"""Training substrate tests: learning, QAT, compression, 8-bit Adam,
microbatching, checkpoint/restart, preemption recovery."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import TokenStream
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.train.loop import build_train_step, init_state, train_loop

CFG = get_config("qwen2-0.5b").reduced()


def _run(steps=40, **kw):
    run = RunConfig(arch="t", steps=steps, lr=3e-3, warmup_steps=5,
                    checkpoint_every=0, **kw)
    data = TokenStream(vocab=CFG.vocab, seq_len=64, global_batch=8)
    state = init_state(jax.random.PRNGKey(0), CFG, run)
    step = build_train_step(CFG, run)
    losses = []
    for _ in range(steps):
        state, m = step(state, data.next_batch())
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases_plain():
    losses, _ = _run()
    assert losses[-1] < losses[0] - 0.5


def test_loss_decreases_with_all_paper_features():
    losses, _ = _run(qat=True, precision_policy="mixed",
                     opt_state_dtype="posit8", grad_compression="posit8",
                     microbatch=2)
    assert losses[-1] < losses[0] - 0.5
    assert np.isfinite(losses).all()


def test_qat_quantizes_forward():
    """With a uniform fp4 policy, effective weights lie on the fp4 grid."""
    from repro.core.policy import PrecisionPolicy
    from repro.core.qat import quantize_tree
    from repro.core import formats as F
    params = {"blk": {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(32, 32)).astype(np.float32))}}
    q = quantize_tree(params, PrecisionPolicy.uniform("fp4"))
    w = np.asarray(q["blk"]["w"])
    scale = np.asarray(jnp.exp2(jnp.ceil(jnp.log2(
        jnp.max(jnp.abs(params["blk"]["w"])) / 6.0))))
    grid = F.code_values(F.FP4)
    grid = np.unique(grid[np.isfinite(grid)]) * scale
    dist = np.min(np.abs(w[..., None] - grid[None, None]), -1)
    assert np.max(dist) < 1e-6


def test_adamw_8bit_tracks_fp32():
    """8-bit moments keep the update *direction* (cosine) and magnitude
    envelope of fp32 Adam; elementwise equality is not expected at 2
    significant digits (convergence equivalence is asserted end-to-end by
    test_loss_decreases_with_all_paper_features)."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * .1)}
    out = {}
    for dt in ("float32", "posit8"):
        cfg = OptConfig(moment_dtype=dt, weight_decay=0.0)
        st = adamw_init(params, cfg)
        p = params
        for _ in range(20):
            p, st = adamw_update(p, g, st, 1e-3, cfg)
        out[dt] = np.asarray(p["w"]) - np.asarray(params["w"])
    a, b = out["float32"].ravel(), out["posit8"].ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert cos > 0.95, cos
    assert 0.5 < np.linalg.norm(b) / np.linalg.norm(a) < 2.0


def test_grad_compression_error_feedback_converges():
    """Error feedback makes the compressed-gradient average unbiased:
    accumulated residuals stay bounded."""
    from repro.parallel import collectives
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(128,)).astype(np.float32))}
    res = jax.tree.map(jnp.zeros_like, g)
    total_q = jnp.zeros((128,))
    for i in range(50):
        gq, res = collectives.error_feedback_update(g, res)
        total_q = total_q + gq["w"]
    # mean of quantized grads ~= true grad (residual bounded, not growing:
    # it stays within one quantization step of the po2 block scale)
    err = np.abs(np.asarray(total_q) / 50 - np.asarray(g["w"])).max()
    assert err < 0.02, err
    assert float(jnp.max(jnp.abs(res["w"]))) < 0.5


def test_microbatch_equals_full_batch_grads():
    run1 = RunConfig(arch="t", steps=1, lr=0.0, warmup_steps=0,
                     grad_clip=0.0, checkpoint_every=0)
    run2 = RunConfig(arch="t", steps=1, lr=0.0, warmup_steps=0,
                     grad_clip=0.0, checkpoint_every=0, microbatch=4)
    data = TokenStream(vocab=CFG.vocab, seq_len=32, global_batch=8)
    batch = data.next_batch()
    s1 = init_state(jax.random.PRNGKey(0), CFG, run1)
    s2 = init_state(jax.random.PRNGKey(0), CFG, run2)
    _, m1 = build_train_step(CFG, run1)(s1, batch)
    _, m2 = build_train_step(CFG, run2)(s2, batch)
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 1e-3


def test_train_loop_checkpoint_resume(tmp_path):
    ck = str(tmp_path / "ck")
    run = RunConfig(arch="t", steps=20, lr=1e-3, warmup_steps=2,
                    checkpoint_every=10, checkpoint_dir=ck)
    data = TokenStream(vocab=CFG.vocab, seq_len=32, global_batch=4)
    state, _ = train_loop(CFG, run, data, log_every=100)
    assert int(state.step) == 20
    # continue to 30 from the persisted checkpoint; data state restored
    run2 = RunConfig(**{**run.__dict__, "steps": 30})
    data2 = TokenStream(vocab=CFG.vocab, seq_len=32, global_batch=4)
    state2, _ = train_loop(CFG, run2, data2, log_every=100)
    assert int(state2.step) == 30
    assert data2.step >= 20  # iterator state resumed, not restarted


def test_train_loop_preemption_recovery(tmp_path):
    """A step that raises mid-run is retried from the last checkpoint."""
    ck = str(tmp_path / "ck")
    run = RunConfig(arch="t", steps=16, lr=1e-3, warmup_steps=2,
                    checkpoint_every=5, checkpoint_dir=ck)
    data = TokenStream(vocab=CFG.vocab, seq_len=32, global_batch=4)
    boom = {"armed": True}

    class FlakyStream(TokenStream):
        def next_batch(self):
            if self.step == 8 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("simulated preemption")
            return super().next_batch()

    flaky = FlakyStream(vocab=CFG.vocab, seq_len=32, global_batch=4)
    try:
        state, _ = train_loop(CFG, run, flaky, log_every=100)
    except RuntimeError:
        # raised outside the step; loop restarts fresh -> second call resumes
        state, _ = train_loop(CFG, run, flaky, log_every=100)
    assert int(state.step) == 16
