"""XR-NPE engine facade: prec_sel modes, zero-operand gating stats."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core import npe
from repro.core.packing import pack


@pytest.mark.parametrize("prec_sel", [0, 1, 2, 3])
def test_simd_dot_matches_dense(prec_sel):
    spec = npe.PREC_SEL[prec_sel]
    rng = np.random.default_rng(prec_sel)
    k = 96
    a_codes = rng.integers(0, spec.ncodes, k)
    b_codes = rng.integers(0, spec.ncodes, k)
    # avoid NaR codes
    a_codes[a_codes == F.nar_code(spec)] = 0
    b_codes[b_codes == F.nar_code(spec)] = 0
    aw = pack(jnp.asarray(a_codes)[None], spec.bits)[0]
    bw = pack(jnp.asarray(b_codes)[None], spec.bits)[0]
    out, stats = npe.simd_dot_packed(aw, bw, k, prec_sel)
    tab = F.code_values(spec).astype(np.float64)
    tab = np.where(np.isnan(tab), 0.0, tab)
    want = float(np.sum(tab[a_codes] * tab[b_codes]))
    assert abs(float(out) - want) < 1e-3 * max(abs(want), 1.0)
    assert stats.lanes_per_word == F.simd_lanes(spec) * 2  # 32b vs 16b lane
    assert stats.operand_bits == spec.bits


def test_power_gating_stats():
    """Half-zero operands -> ~half the MACs power-gated (dark-silicon
    reduction the paper quantifies)."""
    spec = F.POSIT8
    rng = np.random.default_rng(0)
    k = 512
    a = rng.integers(1, 256, k)
    a[a == 128] = 1                   # no NaR
    a[: k // 2] = 0                   # half the stream is zero
    b = rng.integers(1, 128, k)
    aw = pack(jnp.asarray(a)[None], 8)[0]
    bw = pack(jnp.asarray(b)[None], 8)[0]
    _, stats = npe.simd_dot_packed(aw, bw, k, prec_sel=2)
    assert stats.macs_gated >= k // 2
    assert 0.4 < stats.gating_fraction < 0.7
    assert stats.ai_gain_vs_fp32 == pytest.approx(4.0, rel=0.1)
