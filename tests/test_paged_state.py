"""Paged-STATE serving: SSM/RWKV/hybrid families under the continuous
and disaggregated engines, token-for-token against the static oracle.

The pinned invariant: at temperature 0, ``ContinuousEngine`` and
``DisaggEngine`` outputs equal per-request static ``ServeEngine.generate``
(with ``quantized_kv=True, quantized_state=True`` -- the same one-shot
post-prefill quantization and per-step posit8 state round-trip the slab
plane performs) for every ``decode_steps=K``, across chunked prefill,
preemption snapshot/resume, slab-gated admission and the disagg page +
slab handoff."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (ContinuousEngine, DisaggEngine, PagedKVPool,
                         ServeEngine, state_slab_bytes)
from repro.serve.scheduler import RUNNING

RWKV = get_config("rwkv6-1.6b").reduced()
# the reduced hybrid needs a generous MoE capacity factor for exact
# static parity (no dropped tokens between batch layouts)
JAMBA = dataclasses.replace(get_config("jamba-v0.1-52b").reduced(),
                            capacity_factor=8.0)
RWKV_PARAMS = T.lm_init(jax.random.PRNGKey(0), RWKV)
JAMBA_PARAMS = T.lm_init(jax.random.PRNGKey(0), JAMBA)

# prompt lengths must keep the seed scan chunking exact:
# nchunks = max(s // ssm_chunk, 1) must divide s (ssm_chunk = 8)
PROMPTS = [np.arange(1, 13, dtype=np.int32),
           np.arange(3, 11, dtype=np.int32),
           np.arange(5, 11, dtype=np.int32)]
GENS = [6, 5, 7]


def _family(name):
    if name == "rwkv":
        return RWKV, RWKV_PARAMS, dict(max_len=48, page_size=16)
    return JAMBA, JAMBA_PARAMS, dict(max_len=64, page_size=64)


def _oracle(cfg, params, max_len):
    st = ServeEngine(cfg, params, max_len=max_len, quantized_kv=True,
                     quantized_state=True)
    return lambda p, g: st.generate(np.asarray(p, np.int32)[None], g)[0]


def _check(outs, rids, orc):
    for rid, p, g in zip(rids, PROMPTS, GENS):
        np.testing.assert_array_equal(outs[rid], orc(p, g))


@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("family", ["rwkv", "hybrid"])
def test_continuous_matches_static_stateful(family, k):
    cfg, params, kw = _family(family)
    eng = ContinuousEngine(cfg, params, n_pages=8,
                           page_size=kw["page_size"], max_batch=4,
                           max_len=kw["max_len"], decode_steps=k)
    assert eng.pool.has_state
    rids = [eng.submit(p, g) for p, g in zip(PROMPTS, GENS)]
    outs = eng.run()
    _check(outs, rids, _oracle(cfg, params, kw["max_len"]))
    # constant footprint: one slab per live request, never more
    assert eng.pool.slab_alloc_peak <= len(PROMPTS)
    assert eng.pool.used_slabs == 0              # all retired -> freed


def test_continuous_chunked_prefill_stateful():
    """Stateful chunked prefill (unpadded chunks, state carried across
    chunk boundaries) matches the monolithic prefill bitwise."""
    prompts = [np.arange(1, 33, dtype=np.int32),
               np.arange(2, 22, dtype=np.int32)]
    eng = ContinuousEngine(RWKV, RWKV_PARAMS, n_pages=8, page_size=16,
                           max_batch=4, max_len=48, decode_steps=2,
                           prefill_chunk_tokens=16)
    orc = _oracle(RWKV, RWKV_PARAMS, 48)
    rids = [eng.submit(p, 6) for p in prompts]
    outs = eng.run()
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(outs[rid], orc(p, 6))


@pytest.mark.parametrize("family", ["rwkv", "hybrid"])
def test_continuous_preempt_resume_stateful_exact(family):
    """Preempting a RUNNING stateful request snapshots its slab; resume
    imports it bitwise and decoding continues exactly -- no re-prefill,
    nothing charged to wasted_prefill_tokens."""
    cfg, params, kw = _family(family)
    eng = ContinuousEngine(cfg, params, n_pages=8,
                           page_size=kw["page_size"], max_batch=4,
                           max_len=kw["max_len"], decode_steps=1)
    rids = [eng.submit(p, g) for p, g in zip(PROMPTS, GENS)]
    victim = None
    for _ in range(50):
        eng.step()
        victim = next(
            (r for r in eng.scheduler.running if r.status == RUNNING
             and len(r.generated) >= 2 and not r.done), None)
        if victim is not None:
            break
    assert victim is not None
    eng.scheduler.preempt(victim)
    assert victim.resume is not None and "state" in victim.resume
    assert eng.scheduler.wasted_prefill_tokens == 0
    outs = eng.run()
    _check(outs, rids, _oracle(cfg, params, kw["max_len"]))
    assert eng.scheduler.preemption_count == 1
    assert victim.preemptions == 1


def test_continuous_slab_gated_admission():
    """n_state_slabs=1 serializes admission to one live request at a
    time -- the constant-footprint admission gate -- while every
    request still finishes with exact outputs."""
    eng = ContinuousEngine(RWKV, RWKV_PARAMS, n_pages=8, page_size=16,
                           max_batch=4, max_len=48, decode_steps=1,
                           n_state_slabs=1)
    rids = [eng.submit(p, g) for p, g in zip(PROMPTS, GENS)]
    peak_running = 0
    while eng.scheduler.has_work:
        eng.step()
        peak_running = max(peak_running, len(eng.scheduler.running))
        assert eng.pool.used_slabs <= 1
    assert peak_running == 1
    assert eng.pool.slab_alloc_peak == 1
    outs = {rid: req.output for rid, req in eng.scheduler.finished.items()}
    _check(outs, rids, _oracle(RWKV, RWKV_PARAMS, 48))


@pytest.mark.parametrize("family", ["rwkv", "hybrid"])
def test_disagg_matches_static_stateful(family):
    """The nested {state [+ kv]} handoff payload crosses the channel
    bitwise: disagg outputs equal the static oracle's."""
    cfg, params, kw = _family(family)
    eng = DisaggEngine(cfg, params, prefill_pages=8, decode_pages=8,
                       page_size=kw["page_size"], max_batch=4,
                       max_len=kw["max_len"], decode_steps=4)
    rids = [eng.submit(p, g) for p, g in zip(PROMPTS, GENS)]
    outs = eng.run()
    _check(outs, rids, _oracle(cfg, params, kw["max_len"]))
    assert eng.handoffs == len(PROMPTS)
    # every handoff moved at least the state slab's bytes
    assert eng.handoff_bytes >= len(PROMPTS) * state_slab_bytes(cfg)
    assert eng.prefill.pool.used_slabs == 0      # released after export
    assert eng.decode.pool.used_slabs == 0       # freed at retirement


def test_disagg_bounce_resume_stateful_exact():
    """A decode-side bounce of a stateful request snapshots its slab;
    the admitter resumes it bitwise and re-hands it off -- outputs stay
    exact across the round trip."""
    eng = DisaggEngine(RWKV, RWKV_PARAMS, prefill_pages=8, decode_pages=8,
                       page_size=16, max_batch=4, max_len=48,
                       decode_steps=1)
    rids = [eng.submit(p, g) for p, g in zip(PROMPTS, GENS)]
    bounced = False
    steps = 0
    while eng.has_work:
        eng.step()
        steps += 1
        if not bounced:
            run = [r for r in eng.decode.runner.running
                   if r.status == RUNNING and not r.done]
            if run:
                eng.decode.runner.bounce(run[-1])
                bounced = True
        assert steps < 500
    assert bounced and eng.decode.runner.bounce_count == 1
    outs = {rid: req.output for rid, req in eng.finished.items()}
    _check(outs, rids, _oracle(RWKV, RWKV_PARAMS, 48))


def test_state_slab_bytes_model():
    """Closed-form per-kind bytes: a pure-attention config has no slab
    plane; a stateful pool's modeled bytes/step charges one slab read +
    write per live request on top of its live KV pages."""
    dense = get_config("qwen2-0.5b").reduced()
    assert state_slab_bytes(dense) == 0
    sb = state_slab_bytes(RWKV)
    assert sb > 0
    pool = PagedKVPool(RWKV, 0, 16, n_slabs=2)
    assert pool.modeled_bytes_per_step([5]) == pytest.approx(2.0 * sb)
    assert pool.modeled_bytes_per_step([5, 9]) == pytest.approx(4.0 * sb)
    hyb = PagedKVPool(JAMBA, 4, 64, n_slabs=2)
    hsb = state_slab_bytes(JAMBA)
    kv_only = hyb.modeled_bytes_per_step([5]) - 2.0 * hsb
    assert hsb > 0 and kv_only > 0               # both kinds charged
