"""Sharding-rule tests.  These run in a SUBPROCESS with 8 fake devices so
the main pytest process keeps seeing 1 device (the dry-run owns the
512-device configuration; see the system contract in launch/dryrun.py)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


def test_param_rules_on_mesh():
    code = textwrap.dedent("""
        import json, jax
        from jax.sharding import PartitionSpec as P
        def _mk(shape, axes):
            try:
                return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
            except (AttributeError, TypeError):
                return jax.make_mesh(shape, axes)
        mesh = _mk((2, 4), ("data", "model"))
        from repro.parallel.sharding import param_pspec
        out = {}
        # column-parallel default: in->data, out->model
        out["ffn_up"] = str(param_pspec(mesh, "layers/ffn/up/w", (24, 896, 4864)))
        # row-parallel exception: contraction on model
        out["ffn_down"] = str(param_pspec(mesh, "layers/ffn/down/w", (24, 4864, 896)))
        out["attn_wo"] = str(param_pspec(mesh, "layers/attn/wo/w", (24, 1024, 896)))
        # embedding: vocab->model
        out["embed"] = str(param_pspec(mesh, "embed/table", (151936, 896)))
        # norm scale replicated
        out["norm"] = str(param_pspec(mesh, "layers/ln1/norm_scale", (24, 896)))
        # experts: EP on model
        out["experts"] = str(param_pspec(mesh, "layers/moe/experts/up", (61, 384, 7168, 2048)))
        # indivisible dims are dropped, not errors
        out["odd"] = str(param_pspec(mesh, "layers/attn/wq/w", (24, 897, 898)))
        print(json.dumps(out))
    """)
    out = _run_subprocess(code)
    assert "model" in out["ffn_up"] and "data" in out["ffn_up"]
    assert out["ffn_down"].startswith("PartitionSpec(None, 'model'")
    assert out["attn_wo"].startswith("PartitionSpec(None, 'model'")
    assert "'model'" in out["embed"].split(",")[0]
    assert out["norm"] == "PartitionSpec(None, None)" or \
        out["norm"] == "PartitionSpec()"
    assert "'model'" in out["experts"].split(",")[1]
    assert out["odd"] in ("PartitionSpec(None, None, None)",)


def test_train_step_compiles_sharded_and_math_matches():
    """Same train step on 1 device vs an (2,4) mesh: metrics agree."""
    code = textwrap.dedent("""
        import json, jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import RunConfig
        from repro.data import TokenStream
        from repro.train.loop import build_train_step, init_state
        from repro.parallel import sharding as sh
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_config("qwen2-0.5b").reduced()
        run = RunConfig(arch="t", steps=1, lr=1e-3, warmup_steps=0,
                        checkpoint_every=0)
        data = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8)
        batch = data.next_batch()
        state = init_state(jax.random.PRNGKey(0), cfg, run)

        # single-device reference
        s1, m1 = build_train_step(cfg, run)(state, batch)

        # sharded: 2-way data, 4-way model
        def _mk(shape, axes):
            try:
                return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
            except (AttributeError, TypeError):
                return jax.make_mesh(shape, axes)
        mesh = _mk((2, 4), ("data", "model"))
        step_fn, shard_state = build_train_step(cfg, run, mesh=mesh)
        state2 = init_state(jax.random.PRNGKey(0), cfg, run)
        st_sh = shard_state(state2)
        bt_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P("data", *([None]*(x.ndim-1)))),
            batch)
        with sh.use_mesh(mesh):
            f = jax.jit(step_fn, in_shardings=(st_sh, bt_sh),
                        out_shardings=(st_sh, None))
            s2, m2 = f(jax.device_put(state2, st_sh),
                       jax.device_put(batch, bt_sh))
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
    """)
    out = _run_subprocess(code)
    assert abs(out["l1"] - out["l2"]) < 5e-2, out


def test_cache_sharding_rules():
    code = textwrap.dedent("""
        import json, jax
        def _mk(shape, axes):
            try:
                return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
            except (AttributeError, TypeError):
                return jax.make_mesh(shape, axes)
        mesh = _mk((2, 4), ("data", "model"))
        from repro.parallel.sharding import cache_pspec
        out = {}
        # kv cache: batch on data, head_dim on model
        out["kv"] = str(cache_pspec(mesh, "k", (24, 8, 512, 2, 64), batch=8))
        # B=1 long-context: seq takes the data axes (SP)
        out["kv_sp"] = str(cache_pspec(mesh, "k", (4, 1, 1024, 8, 128), batch=1))
        print(json.dumps(out))
    """)
    out = _run_subprocess(code)
    assert "'data'" in out["kv"] and "'model'" in out["kv"]
    kv_sp = out["kv_sp"]
    assert kv_sp.index("data") > 0  # seq axis got the data shard
