"""KV decode plane: fused flash-decode kernel vs oracle, the XLA blocked
fallback, quantized-vs-bf16 decode parity, and the quantized_kv=True
serving path end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import PrecisionPolicy
from repro.kernels import ref
from repro.kernels.flash_decode import default_kv_block, flash_decode_pallas
from repro.models import attention as A
from repro.models import transformer as T
from repro.models import zoo
from repro.serve.engine import ServeEngine

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(0)


def _quantized_cache(b=2, t=64, kh=2, dh=32, group=None):
    k = jnp.asarray(RNG.normal(size=(b, t, kh, dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, kh, dh)).astype(np.float32))
    kc, ks = A.quantize_kv(k, group)
    vc, vs = A.quantize_kv(v, group)
    return {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}


# ---------------------------------------------------------------------------
# kernel / fallback vs the naive oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pos", [0, 5, 31, 63])
@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("group", [None, 8])
def test_flash_kernel_vs_oracle(pos, softcap, group):
    cache = _quantized_cache(group=group)
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 32)).astype(np.float32))
    got = flash_decode_pallas(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], jnp.int32(pos), blk=16, softcap=softcap,
        interpret=True)
    want = ref.flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pos, softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pos", [0, 7, 40, 63])
@pytest.mark.parametrize("group", [None, 16])
def test_blocked_xla_vs_oracle(pos, group):
    cache = _quantized_cache(group=group)
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 32)).astype(np.float32))
    got = jax.jit(A.decode_quantized_blocks)(q, cache, jnp.int32(pos))
    want = ref.flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_matches_blocked_with_softcap():
    """Both live paths agree with each other (same math, different
    schedule) including the softcap nonlinearity."""
    cache = _quantized_cache()
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 32)).astype(np.float32))
    a = flash_decode_pallas(q, cache["k_codes"], cache["k_scale"],
                            cache["v_codes"], cache["v_scale"],
                            jnp.int32(41), softcap=30.0, interpret=True)
    b = A.decode_quantized_blocks(q, cache, jnp.int32(41), softcap=30.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ragged batches: the per-row pad operand
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("group", [None, 8])
def test_flash_kernel_pad_vs_oracle(softcap, group):
    """Per-row left-pad widths mask cache slots below pad[b] inside the
    kernel's online softmax -- ragged static batches need no fallback."""
    cache = _quantized_cache(group=group)
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 32)).astype(np.float32))
    pad = jnp.asarray([3, 17], jnp.int32)
    got = flash_decode_pallas(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], jnp.int32(41), pad=pad, blk=16,
        softcap=softcap, interpret=True)
    want = ref.flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], 41, softcap, pad=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pos", [5, 40, 63])
def test_flash_kernel_pad_matches_blocked(pos):
    """Kernel and XLA fallback agree on ragged batches, including a row
    whose pad covers whole KV blocks (blocks fully below pad mask to
    exact zeros) and a row with no padding at all."""
    cache = _quantized_cache()
    q = jnp.asarray(RNG.normal(size=(2, 2, 2, 32)).astype(np.float32))
    pad = jnp.asarray([0, min(pos, 33)], jnp.int32)
    a = flash_decode_pallas(q, cache["k_codes"], cache["k_scale"],
                            cache["v_codes"], cache["v_scale"],
                            jnp.int32(pos), pad=pad, blk=16,
                            interpret=True)
    b = A.decode_quantized_blocks(q, cache, jnp.int32(pos), blk=16,
                                  pad=pad)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("group", [None, 8])
def test_flash_kernel_pad_skip_whole_blocks(softcap, group):
    """Pads covering WHOLE KV blocks: the index map now clamps those
    blocks onto the first live one (they are never fetched) and the
    compute gate skips them -- the output must still match the oracle's
    mask-everything path, including a pad-free row and a row whose pad
    is a multiple of the block size."""
    cache = _quantized_cache(b=3)
    q = jnp.asarray(RNG.normal(size=(3, 2, 2, 32)).astype(np.float32))
    pad = jnp.asarray([0, 16, 48], jnp.int32)    # 0, 1 and 3 whole blocks
    got = flash_decode_pallas(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], jnp.int32(55), pad=pad, blk=16,
        softcap=softcap, interpret=True)
    want = ref.flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], 55, softcap, pad=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pos", [33, 47, 63])
def test_flash_kernel_pad_skip_matches_blocked(pos):
    """Kernel (skip-below-pad index map) vs the XLA blocked fallback
    (which still masks below-pad slots): same result on rows whose pad
    skips whole blocks, lands mid-block, or equals pos (a single live
    slot -- the smallest legal window)."""
    cache = _quantized_cache(b=3)
    q = jnp.asarray(RNG.normal(size=(3, 2, 2, 32)).astype(np.float32))
    pad = jnp.asarray([32, 19, pos], jnp.int32)
    a = flash_decode_pallas(q, cache["k_codes"], cache["k_scale"],
                            cache["v_codes"], cache["v_scale"],
                            jnp.int32(pos), pad=pad, blk=16,
                            interpret=True)
    b = A.decode_quantized_blocks(q, cache, jnp.int32(pos), blk=16,
                                  pad=pad)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_engine_ragged_generate_flash_matches_blocked():
    """lengths= (ragged static batch) no longer forces the blocked
    fallback under decode_impl='flash': both paths emit the same
    tokens."""
    cfg = dataclasses.replace(CFG, decode_impl="flash")
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    toks = np.zeros((3, 9), np.int32)
    lens = np.asarray([4, 9, 6])
    rng = np.random.default_rng(5)
    for i, ln in enumerate(lens):
        toks[i, 9 - ln:] = rng.integers(0, cfg.vocab, (ln,))
    toks = jnp.asarray(toks)
    out_fl = ServeEngine(cfg, params, max_len=32, quantized_kv=True) \
        .generate(toks, steps=5, lengths=lens)
    out_bl = ServeEngine(CFG, params, max_len=32, quantized_kv=True) \
        .generate(toks, steps=5, lengths=lens)
    np.testing.assert_array_equal(out_fl, out_bl)


# ---------------------------------------------------------------------------
# unified scale layout (quant.group_scales along Dh)
# ---------------------------------------------------------------------------

def test_quantize_kv_group_layout():
    k = jnp.asarray(RNG.normal(size=(2, 8, 2, 32)).astype(np.float32))
    codes, s = A.quantize_kv(k)                     # per-(token, head)
    assert codes.shape == (2, 8, 2, 32) and s.shape == (2, 8, 2, 1)
    codes_g, s_g = A.quantize_kv(k, group_size=8)   # Dh-grouped
    assert s_g.shape == (2, 8, 2, 4)
    # group >= Dh (and non-divisors) degenerate to per-(token, head)
    assert A.quantize_kv(k, group_size=32)[1].shape == (2, 8, 2, 1)
    assert A.quantize_kv(k, group_size=7)[1].shape == (2, 8, 2, 1)
    # both layouts round-trip at posit8-level error
    err = jnp.mean(jnp.abs(A.dequantize_kv(codes, s, jnp.float32) - k))
    err_g = jnp.mean(jnp.abs(A.dequantize_kv(codes_g, s_g, jnp.float32) - k))
    assert float(err) < 0.1 and float(err_g) < 0.1


def test_kv_block_divides():
    for ml in (32, 64, 96, 128, 256, 2048):
        blk = default_kv_block(ml)
        assert ml % blk == 0 and blk <= 128


# ---------------------------------------------------------------------------
# quantized vs bf16 decode parity over many steps
# ---------------------------------------------------------------------------

def test_quantized_kv_parity_32_steps():
    """Greedy decode with a posit8 cache stays within posit8 tolerance of
    the bf16 cache for >= 32 consecutive steps (same token stream)."""
    params = T.lm_init(jax.random.PRNGKey(0), CFG)
    B, steps = 2, 33
    cache_f = T.init_cache(CFG, B, steps + 1, quantized_kv=False)
    cache_q = T.init_cache(CFG, B, steps + 1, quantized_kv=True)
    tok = jnp.asarray(RNG.integers(0, CFG.vocab, (B, 1)), jnp.int32)
    step = jax.jit(lambda p, t, c, i: zoo.decode_model(p, t, CFG, c, i))
    worst = 0.0
    for i in range(steps):
        lf, cache_f = step(params, tok, cache_f, jnp.int32(i))
        lq, cache_q = step(params, tok, cache_q, jnp.int32(i))
        pf = jax.nn.softmax(lf.astype(jnp.float32), -1)
        pq = jax.nn.softmax(lq.astype(jnp.float32), -1)
        worst = max(worst, float(jnp.max(jnp.abs(pf - pq))))
        tok = jnp.argmax(lf[:, -1], -1)[:, None].astype(jnp.int32)
    assert worst < 0.05, worst


# ---------------------------------------------------------------------------
# quantized_kv=True end-to-end serving
# ---------------------------------------------------------------------------

def test_engine_generate_quantized_kv():
    params = T.lm_init(jax.random.PRNGKey(0), CFG)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, CFG.vocab, (2, 8)), jnp.int32)
    out_f = ServeEngine(CFG, params, max_len=64).generate(toks, steps=6)
    eng_q = ServeEngine(CFG, params, max_len=64, quantized_kv=True)
    # prefill really returns codes, not a bf16 cache
    _, cache = eng_q._prefill(eng_q.params, {"tokens": toks})
    flat = jax.tree_util.tree_leaves_with_path(cache)
    assert any(x.dtype == jnp.uint8 for _, x in flat)
    assert not any(p[-1].key in ("k", "v") for p, _ in flat
                   if hasattr(p[-1], "key"))
    out_q = eng_q.generate(toks, steps=6)
    assert out_q.shape == (2, 14) and np.isfinite(out_q).all()
    # posit8 KV is near-lossless on this model: greedy tokens agree
    assert (out_q == out_f).mean() > 0.9


def test_engine_generate_quantized_kv_grouped_policy():
    """PrecisionPolicy.group_size grids the KV plane like the weights."""
    params = T.lm_init(jax.random.PRNGKey(0), CFG)
    pol = PrecisionPolicy(rules=[], default="posit8_0", group_size=16)
    eng = ServeEngine(CFG, params, max_len=48, quantized_kv=True, policy=pol)
    _, cache = eng._prefill(eng.params, {"tokens": jnp.zeros((1, 4),
                                                             jnp.int32)})
    scales = [x for p, x in jax.tree_util.tree_leaves_with_path(cache)
              if hasattr(p[-1], "key") and p[-1].key == "k_scale"]
    assert scales and all(s.shape[-1] == 2 for s in scales)  # Dh=32 / 16
    out = eng.generate(jnp.zeros((1, 4), jnp.int32), steps=4)
    assert out.shape == (1, 8) and np.isfinite(out).all()


def test_engine_generate_flash_impl():
    """cfg.decode_impl='flash' serves through the fused Pallas kernel."""
    cfg = dataclasses.replace(CFG, decode_impl="flash")
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab, (2, 6)), jnp.int32)
    eng_fl = ServeEngine(cfg, params, max_len=32, quantized_kv=True)
    eng_bl = ServeEngine(CFG, params, max_len=32, quantized_kv=True)
    out_fl = eng_fl.generate(toks, steps=4)
    out_bl = eng_bl.generate(toks, steps=4)
    np.testing.assert_array_equal(out_fl, out_bl)


def test_engine_generate_quantized_kv_hybrid():
    """Hybrid (attn + mamba) caches quantize their attention sub-blocks
    only; mamba states pass through and decode still works."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    params = T.lm_init(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(np.random.default_rng(4).integers(
        0, cfg.vocab, (2, 4)), jnp.int32)
    out = ServeEngine(cfg, params, max_len=32,
                      quantized_kv=True).generate(toks, steps=3)
    assert out.shape == (2, 7) and np.isfinite(out).all()


# ---------------------------------------------------------------------------
# structure-aware cache padding
# ---------------------------------------------------------------------------

def test_pad_cache_structure_aware():
    params = T.lm_init(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, max_len=40, quantized_kv=True)
    _, cache = eng._prefill(eng.params, {"tokens": jnp.zeros((2, 8),
                                                             jnp.int32)})
    padded = eng._pad_cache(cache, 2)
    for path, x in jax.tree_util.tree_leaves_with_path(padded):
        key = path[-1].key
        assert x.shape[2] == 40, (key, x.shape)      # seq axis is axis 2
        if key.endswith("_scale"):
            assert x.shape[-1] == 1                   # scale cols intact
    # state tensors (no seq axis) must pass through untouched
    ssm_cfg = get_config("rwkv6-1.6b").reduced()
    ssm_params = T.lm_init(jax.random.PRNGKey(2), ssm_cfg)
    ssm_eng = ServeEngine(ssm_cfg, ssm_params, max_len=40)
    _, state = ssm_eng._prefill(ssm_params, {"tokens": jnp.zeros((2, 8),
                                                                 jnp.int32)})
    repadded = ssm_eng._pad_cache(state, 2)
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_leaves_with_path(state),
            jax.tree_util.tree_leaves_with_path(repadded)):
        assert a.shape == b.shape, (p1, a.shape, b.shape)
