"""The unified codec/data plane: registry dispatch, group-scale
round-trips, the rank-generic pack path, and checkpoint/QAT threading."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec as C
from repro.core import formats as F
from repro.core import quant
from repro.core.policy import PrecisionPolicy
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)
PAPER_FORMATS = [F.FP4, F.POSIT4, F.POSIT8, F.POSIT16]
GROUPS = [32, 128, None]  # None = per-channel (group=K special case)


# ---------------------------------------------------------------------------
# codec registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", PAPER_FORMATS + [F.FP8_E4M3, F.FXP8],
                         ids=lambda s: s.name)
def test_codec_paths_agree(spec):
    """Table and algorithmic paths give the same codes/values through the
    registry API: eager (small/concrete -> table) vs jit (traced ->
    algorithmic)."""
    cod = C.get_codec(spec)
    x = jnp.asarray(RNG.normal(size=512).astype(np.float32)) * 3.0
    enc_tab = cod.encode(x)                 # concrete + small -> table
    enc_alg = jax.jit(cod.encode)(x)        # traced -> algorithmic
    # codes may differ only at +-0 (the table dedups to the +0 code);
    # the decoded VALUES must agree exactly
    assert np.array_equal(np.asarray(cod.decode(enc_tab)),
                          np.asarray(cod.decode(enc_alg)))
    dec_tab = np.asarray(cod.decode(enc_tab))
    dec_alg = np.asarray(jax.jit(cod.decode)(enc_tab))
    assert np.array_equal(dec_tab, dec_alg)
    q_tab = np.asarray(cod.quantize(x))
    q_alg = np.asarray(jax.jit(cod.quantize)(x))
    assert np.array_equal(q_tab, q_alg)


def test_codec_registry_covers_all_formats():
    for spec in F.FORMATS.values():
        cod = C.get_codec(spec)
        assert cod.spec is spec


def test_codec_unknown_kind_raises():
    bogus = dataclasses.replace(F.FP4, kind="unobtainium")
    with pytest.raises(ValueError, match="no codec registered"):
        C.get_codec(bogus)


def test_codec_nar_decodes_to_zero_on_both_paths():
    """Hardware exception semantics: NaR/NaN codes feed 0 to the
    accumulator on the table AND algorithmic paths."""
    cod = C.get_codec(F.POSIT8)
    nar = jnp.asarray([F.nar_code(F.POSIT8)])
    assert float(cod.decode(nar)[0]) == 0.0
    assert float(jax.jit(cod.decode)(nar)[0]) == 0.0


# ---------------------------------------------------------------------------
# group-scale round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group", GROUPS, ids=lambda g: f"g{g}")
@pytest.mark.parametrize("spec", PAPER_FORMATS, ids=lambda s: s.name)
def test_group_roundtrip_exact_on_grid(spec, group):
    """pack_tensor -> to_dense is EXACT for tensors already on the
    format's (scaled) value grid: decode(encode(v)) == v for every
    representable v, and po2 group scales divide out exactly."""
    k, n = 300, 96
    g = group or k
    # build per-(group, channel) po2 scales, then values on the grid
    vals = F.code_values(spec)
    vals = vals[np.isfinite(vals)]
    ngroups = -(-k // g)
    scales = 2.0 ** RNG.integers(-2, 3, size=(ngroups, n))
    grid = RNG.choice(vals, size=(k, n)).astype(np.float32)
    # pin each group's absmax to the format's max finite value so the
    # absmax_po2 pack scale reproduces the generating scale exactly
    grid[::g, :] = np.nanmax(np.abs(vals)).astype(np.float32)
    w = grid * np.repeat(scales, g, axis=0)[:k].astype(np.float32)
    t = ops.pack_tensor(spec, jnp.asarray(w), scale_method="absmax_po2",
                        group_size=group)
    d = np.asarray(ops.to_dense(t))
    assert d.shape == w.shape
    np.testing.assert_array_equal(d, w)


@pytest.mark.parametrize("group", GROUPS, ids=lambda g: f"g{g}")
def test_nd_stacked_roundtrip(group):
    """N-D (scan/expert-stacked) weights go through the same rank-generic
    path: slicing the packed leaves matches packing each slice."""
    w = jnp.asarray(RNG.normal(size=(3, 2, 160, 64)).astype(np.float32))
    t = ops.pack_tensor(F.POSIT8, w, group_size=group)
    d = ops.to_dense(t)
    assert d.shape == w.shape
    rel = float(jnp.linalg.norm(d - w) / jnp.linalg.norm(w))
    assert rel < 0.02, rel
    # lax.scan-style leaf slicing == slice-wise packing
    for i in (0, 2):
        for j in (0, 1):
            sl = jax.tree.map(lambda x: x[i, j], t)
            t2 = ops.pack_tensor(F.POSIT8, w[i, j], group_size=group)
            np.testing.assert_array_equal(np.asarray(ops.to_dense(sl)),
                                          np.asarray(ops.to_dense(t2)))


@pytest.mark.parametrize("group", [32, 64, None], ids=lambda g: f"g{g}")
@pytest.mark.parametrize("spec", PAPER_FORMATS, ids=lambda s: s.name)
def test_grouped_matmul_matches_f32_oracle(spec, group):
    """packed_matmul (kernel AND ref paths) with per-group scales matches
    the f32 oracle to_dense + jnp.dot."""
    m, k, n = 9, 200, 130
    w = jnp.asarray(RNG.normal(size=(k, n)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    t = ops.pack_tensor(spec, w, group_size=group)
    oracle = x @ ops.to_dense(t)
    for use_ref in (False, True):
        out = ops.packed_matmul(x, t, use_ref=use_ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=3e-6, atol=1e-4)


def test_group_scaling_beats_per_channel_on_heterogeneous_weights():
    """The accuracy lever: per-group scales track K-local dynamic range
    that one per-channel scale cannot."""
    prof = np.exp(RNG.normal(size=(256, 1)) * 1.2)
    w = jnp.asarray((RNG.normal(size=(256, 64)) * prof).astype(np.float32))
    errs = {}
    for g in (None, 64):
        d = ops.to_dense(ops.pack_tensor(F.FP4, w, group_size=g))
        errs[g] = float(jnp.linalg.norm(d - w) / jnp.linalg.norm(w))
    assert errs[64] < errs[None], errs


def test_fake_quant_group_matches_pack_grid():
    """QAT trains against the serving grid: grouped fake_quant equals the
    pack_tensor -> to_dense round-trip on the same grouping."""
    w = jnp.asarray(RNG.normal(size=(128, 48)).astype(np.float32))
    fq = quant.fake_quant(F.FP4, w, group_size=32)
    d = ops.to_dense(ops.pack_tensor(F.FP4, w, group_size=32))
    np.testing.assert_allclose(np.asarray(fq), np.asarray(d),
                               rtol=1e-6, atol=1e-7)


def test_entropy_scale_method_packs():
    """The eq.(3) entropy scheme (scalar per-tensor scale) flows through
    the rank-generic pack path: broadcast to the per-channel layout."""
    w = jnp.asarray(RNG.normal(size=(64, 32)).astype(np.float32))
    t = ops.pack_tensor(F.FXP8, w, scale_method="entropy")
    assert t.scales.shape[0] == 1
    d = ops.to_dense(t)
    rel = float(jnp.linalg.norm(d - w) / jnp.linalg.norm(w))
    assert rel < 0.2, rel


def test_group_scales_ignore_padding_tail():
    """A K not divisible by the group: the tail group's statistic uses
    only real rows (zero padding must not skew rms)."""
    w = RNG.normal(size=(100, 8)).astype(np.float32)
    s_full = quant.group_scales(F.POSIT4, jnp.asarray(w[:96]), 32)
    s_tail = quant.group_scales(F.POSIT4, jnp.asarray(w), 32)
    np.testing.assert_array_equal(np.asarray(s_full),
                                  np.asarray(s_tail)[:3])
    # tail group scale from its 4 real rows only
    expect = quant.group_scales(F.POSIT4, jnp.asarray(w[96:]), 32)
    np.testing.assert_allclose(np.asarray(s_tail)[3:], np.asarray(expect))


# ---------------------------------------------------------------------------
# policy / checkpoint threading
# ---------------------------------------------------------------------------

def test_policy_group_field_roundtrips_json():
    pol = PrecisionPolicy.uniform("fp4")
    pol.group_size = 64
    pol2 = PrecisionPolicy.from_json(pol.to_json())
    assert pol2.group_size == 64
    assert pol2.group_for("layers/ffn/up/w") == 64
    assert pol2.group_for("layers/ln1/norm_scale") is None  # keep_fp32
    # back-compat: old json without the field
    import json
    d = json.loads(pol.to_json())
    del d["group_size"]
    assert PrecisionPolicy.from_json(json.dumps(d)).group_size is None


def test_packed_tensor_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    w = jnp.asarray(RNG.normal(size=(160, 64)).astype(np.float32))
    t = ops.pack_tensor(F.FP4, w, group_size=32)
    tree = {"layer": {"w": t, "b": jnp.zeros(64)}}
    save_checkpoint(str(tmp_path), 1, tree)
    t2, _, _ = restore_checkpoint(str(tmp_path), tree)
    r = t2["layer"]["w"]
    assert isinstance(r, ops.PackedTensor)
    assert r.spec is F.FP4 and r.group == 32 and r.shape == (160, 64)
    assert r.version == ops.PACKED_TENSOR_VERSION
    np.testing.assert_array_equal(np.asarray(r.words), np.asarray(t.words))
    np.testing.assert_array_equal(np.asarray(ops.to_dense(r)),
                                  np.asarray(ops.to_dense(t)))


def test_packed_aux_inside_dataclass_tree(tmp_path):
    """PackedTensors nested in dataclass containers (TrainState-style)
    get manifest aux too: the saved layout wins over the template's."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    @dataclasses.dataclass
    class State:
        params: dict

    w = jnp.asarray(RNG.normal(size=(160, 64)).astype(np.float32))
    st = State(params={"w": ops.pack_tensor(F.FP4, w, group_size=32)})
    save_checkpoint(str(tmp_path), 1, st)
    import json, os
    with open(os.path.join(tmp_path, "step_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["packed"]["params/w"]["group"] == 32
    # restore through a template whose aux disagrees: disk wins
    stale = State(params={"w": ops.pack_tensor(F.POSIT8, w, group_size=None)})
    r, _, _ = restore_checkpoint(str(tmp_path), stale)
    assert r.params["w"].group == 32 and r.params["w"].spec is F.FP4


def test_pack_params_threads_policy_group():
    from repro.models import zoo
    pol = PrecisionPolicy.uniform("posit8_0")
    pol.group_size = 32
    params = {"blk": {"ffn": {"w": jnp.asarray(
        RNG.normal(size=(128, 64)).astype(np.float32))}}}
    packed = zoo.pack_params(params, pol)
    t = packed["blk"]["ffn"]["w"]
    assert isinstance(t, ops.PackedTensor) and t.group == 32
    assert t.scales.shape[0] == t.words.shape[0] // 32
