"""Paged-KV plane: kernel/fallback parity vs the oracle, pool
bookkeeping, paged-vs-contiguous decode equivalence, ragged static
serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.flash_decode import (paged_flash_decode_pallas,
                                        paged_flash_prefill_pallas)
from repro.models import attention as A
from repro.models import transformer as T
from repro.serve import PagedKVPool, ServeEngine, paged_kv_bytes_per_step

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(0)


def _paged_setup(b=3, psize=16, n_pages=15, npp=4, kh=2, dh=32, group=None):
    """Random pool pages + disjoint per-request page tables (page 0 is
    the parking page, never referenced live)."""
    P = n_pages + 1
    k = RNG.normal(size=(P, psize, kh, dh)).astype(np.float32)
    v = RNG.normal(size=(P, psize, kh, dh)).astype(np.float32)
    kc, ks = A.quantize_kv(jnp.asarray(k), group)
    vc, vs = A.quantize_kv(jnp.asarray(v), group)
    pages = RNG.permutation(np.arange(1, P))[: b * npp].reshape(b, npp)
    pt = jnp.asarray(pages.astype(np.int32))
    return {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}, pt


# ---------------------------------------------------------------------------
# paged kernel / fallback vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("positions", [[0, 0, 0], [5, 33, 60], [63, 1, 17]])
@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("group", [None, 8])
def test_paged_kernel_vs_oracle(positions, softcap, group):
    cache, pt = _paged_setup(group=group)
    q = jnp.asarray(RNG.normal(size=(3, 2, 2, 32)).astype(np.float32))
    pos = jnp.asarray(positions, jnp.int32)
    got = paged_flash_decode_pallas(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pt, pos, softcap=softcap, interpret=True)
    want = ref.paged_flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pt, pos, softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("positions", [[2, 40, 63], [63, 63, 63]])
def test_paged_blocked_vs_oracle(positions):
    cache, pt = _paged_setup()
    q = jnp.asarray(RNG.normal(size=(3, 2, 2, 32)).astype(np.float32))
    pos = jnp.asarray(positions, jnp.int32)
    got = jax.jit(A.paged_decode_blocked)(q, cache, pt, pos)
    want = ref.paged_flash_decode_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pt, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_matches_contiguous_bitwise():
    """Scattering a contiguous cache into (shuffled) pages and decoding
    through the page table reproduces the contiguous blocked decode
    BITWISE when page size == the KV block: one block partition, one
    accumulation order -- the invariant ContinuousEngine's token parity
    rests on."""
    b, t, kh, dh, psize = 2, 64, 2, 32, 16
    k = RNG.normal(size=(b, t, kh, dh)).astype(np.float32)
    v = RNG.normal(size=(b, t, kh, dh)).astype(np.float32)
    kc, ks = A.quantize_kv(jnp.asarray(k))
    vc, vs = A.quantize_kv(jnp.asarray(v))
    contig = {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}
    npp = t // psize
    # scatter each request's blocks into a shuffled shared pool
    perm = RNG.permutation(np.arange(1, b * npp + 1))
    pt = perm.reshape(b, npp).astype(np.int32)
    pool = {}
    for key, x in contig.items():
        xp = np.asarray(x).reshape(b, npp, psize, *x.shape[2:])
        buf = np.zeros((b * npp + 1,) + xp.shape[2:], xp.dtype)
        buf[pt.reshape(-1)] = xp.reshape(-1, *xp.shape[2:])
        pool[key] = jnp.asarray(buf)
    q = jnp.asarray(RNG.normal(size=(b, kh, 2, dh)).astype(np.float32))
    for pos_pair in ([5, 60], [17, 17], [0, 63]):
        pos = jnp.asarray(pos_pair, jnp.int32)
        paged = A.paged_decode_blocked(q, pool, jnp.asarray(pt), pos)
        for i, p in enumerate(pos_pair):
            contig_i = A.decode_quantized_blocks(
                q[i:i + 1], {k_: v_[i:i + 1] for k_, v_ in contig.items()},
                jnp.int32(p), blk=psize)
            np.testing.assert_array_equal(np.asarray(paged[i:i + 1]),
                                          np.asarray(contig_i))


# ---------------------------------------------------------------------------
# paged chunk-PREFILL kernel / fallback vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start", [[0, 0, 0], [16, 0, 32], [32, 16, 48]])
@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("group", [None, 8])
def test_paged_prefill_kernel_vs_oracle(start, softcap, group):
    """The paged chunk-prefill kernel (interpret on CPU) and the XLA
    fallback both reproduce the gather-then-causal-softmax oracle for
    chunks starting anywhere in the page table."""
    cache, pt = _paged_setup(group=group)
    c = 16
    q = jnp.asarray(RNG.normal(size=(3, c, 2, 2, 32)).astype(np.float32))
    st = jnp.asarray(start, jnp.int32)
    want = ref.paged_prefill_ref(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pt, st, softcap)
    got = paged_flash_prefill_pallas(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], pt, st, softcap=softcap, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    blocked = jax.jit(A.paged_prefill_blocked,
                      static_argnames=("softcap",))(
        q, cache, pt, st, softcap=softcap)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_prefill_chunk_rows_match_decode():
    """A chunk row at position p computes the same attention as a
    single decoded query at position p (the C=1 degenerate case closes
    the loop between the prefill and decode paged paths)."""
    cache, pt = _paged_setup()
    q = jnp.asarray(RNG.normal(size=(3, 1, 2, 2, 32)).astype(np.float32))
    pos = jnp.asarray([5, 33, 60], jnp.int32)
    chunk = A.paged_prefill_blocked(q, cache, pt, pos)          # C=1
    dec = A.paged_decode_blocked(q[:, 0], cache, pt, pos)
    np.testing.assert_allclose(np.asarray(chunk[:, 0]), np.asarray(dec),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# pool bookkeeping
# ---------------------------------------------------------------------------

def test_pool_alloc_free_utilization():
    pool = PagedKVPool(CFG, n_pages=8, page_size=16)
    assert pool.free_pages == 8 and pool.utilization == 0.0
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert len(a) == 3 and len(b) == 4 and not (set(a) & set(b))
    assert 0 not in a + b                     # parking page never allocated
    assert pool.alloc(2) is None              # 1 page left: refused, intact
    assert pool.free_pages == 1
    assert pool.utilization == pytest.approx(7 / 8)
    pool.free(a)
    assert pool.free_pages == 4 and pool.alloc_peak == 7
    with pytest.raises(AssertionError):       # double free is a bug
        pool.free([a[0]])


def test_pool_pages_for():
    pool = PagedKVPool(CFG, n_pages=4, page_size=16)
    assert [pool.pages_for(n) for n in (1, 16, 17, 32, 33)] == [1, 1, 2, 2, 3]


def test_pool_page_kinds_and_family_gate():
    """Page kinds derive from the config's layer mix; an unknown family
    is rejected with the supported families named in the error."""
    import dataclasses
    assert PagedKVPool.page_kinds(CFG) == ("kv",)
    ssm_cfg = get_config("rwkv6-1.6b").reduced()
    assert PagedKVPool.page_kinds(ssm_cfg) == ("state",)
    assert PagedKVPool.page_kinds(
        get_config("jamba-v0.1-52b").reduced()) == ("kv", "state")
    with pytest.raises(ValueError, match="dense.*hybrid.*moe.*ssm"):
        PagedKVPool(dataclasses.replace(CFG, family="mystery"), 4, 16)
    # a stateful pool now constructs -- with the slab plane sized in
    # and no KV page plane at all
    pool = PagedKVPool(ssm_cfg, 0, 16, n_slabs=3)
    assert pool.has_state and not pool.has_kv
    assert pool.n_slabs == 3 and pool.free_slabs == 3
    assert pool.pages_for(100) == 0              # nothing ever pages


def test_pool_prefill_roundtrip():
    """write_prefill + gather_request reproduce the quantized prefill
    cache exactly (pure data movement, no recoding)."""
    pool = PagedKVPool(CFG, n_pages=6, page_size=8)
    L, kh, dh = CFG.n_layers, CFG.n_kv_heads, CFG.resolved_head_dim
    cache_q = {}
    for key, dt, cols in (("k_codes", np.uint8, dh), ("v_codes", np.uint8, dh),
                          ("k_scale", np.float32, 1),
                          ("v_scale", np.float32, 1)):
        x = RNG.integers(0, 255, (L, 1, 16, kh, cols)).astype(dt)
        cache_q[key] = jnp.asarray(x).astype(
            jnp.uint8 if dt == np.uint8 else jnp.bfloat16)
    pages = pool.alloc(3)                      # one spare page
    pool.write_prefill(cache_q, pages)
    back = pool.gather_request(pages[:2])
    for key in cache_q:
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(cache_q[key]))


def test_pool_free_guard_stays_consistent_under_churn():
    """Alloc/free churn against a shadow model: the allocated-page set
    (the O(1) replacement of the old O(P) ``pg not in free`` scan) and
    the free list must partition the pool at every step, and the
    double-free guard must keep firing."""
    pool = PagedKVPool(CFG, n_pages=128, page_size=16)
    rng = np.random.default_rng(7)
    held = []
    for _ in range(300):
        if held and rng.random() < 0.45:
            pages = held.pop(rng.integers(0, len(held)))
            pool.free(pages)
        else:
            got = pool.alloc(int(rng.integers(1, 9)))
            if got is not None:
                held.append(got)
        live = [pg for pages in held for pg in pages]
        assert len(live) == len(set(live)) == pool.used_pages
        assert pool.free_pages + pool.used_pages == pool.n_pages
        assert set(live) == pool._allocated
    for pages in held:
        pool.free(pages)
    assert pool.used_pages == 0
    got = pool.alloc(2)
    with pytest.raises(AssertionError):
        pool.free([got[0], got[0]])              # double free still fires


def test_pool_refcount_share_and_decref():
    """Prefix-sharing refcounts: incref adds a holder, free is a decref
    that only returns the page once the last holder lets go, and the
    double-free / incref-of-free guards still fire."""
    pool = PagedKVPool(CFG, n_pages=4, page_size=8)
    (pg,) = pool.alloc(1)
    assert pool.refcount(pg) == 1
    pool.incref([pg])                          # a sharer attaches
    assert pool.refcount(pg) == 2
    pool.free([pg])                            # decref: still allocated
    assert pool.refcount(pg) == 1 and pool.used_pages == 1
    pool.free([pg])                            # last holder: really freed
    assert pool.refcount(pg) == 0 and pool.used_pages == 0
    with pytest.raises(AssertionError):
        pool.free([pg])                        # double free still a bug
    with pytest.raises(AssertionError):
        pool.incref([pg])                      # can't share a free page


def test_pool_refcount_churn_invariants():
    """Alloc/incref/decref churn against a shadow refcount model: the
    allocated set must stay exactly the pages with refcount >= 1, and
    the free list + allocated set must partition the pool throughout."""
    pool = PagedKVPool(CFG, n_pages=64, page_size=16)
    rng = np.random.default_rng(11)
    ref = {}                                   # shadow refcounts
    for _ in range(400):
        r = rng.random()
        live = sorted(ref)
        if live and r < 0.3:
            pg = live[rng.integers(0, len(live))]
            pool.incref([pg])
            ref[pg] += 1
        elif live and r < 0.65:
            pg = live[rng.integers(0, len(live))]
            pool.free([pg])
            ref[pg] -= 1
            if ref[pg] == 0:
                del ref[pg]
        else:
            got = pool.alloc(int(rng.integers(1, 5)))
            if got is not None:
                for pg in got:
                    ref[pg] = 1
        assert pool.used_pages == len(ref)
        assert pool.free_pages + pool.used_pages == pool.n_pages
        assert all(pool.refcount(pg) == n for pg, n in ref.items())
        assert pool._allocated == set(ref)
    for pg, n in list(ref.items()):
        for _ in range(n):
            pool.free([pg])
    assert pool.used_pages == 0


def test_pool_mixed_kind_churn_invariants():
    """Interleaved KV-page AND state-slab alloc/incref/free churn on a
    hybrid pool, against one shadow refcount model per kind: the two
    planes must stay independent, each must partition its resource at
    every step, and releasing every holder leaks nothing."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    pool = PagedKVPool(cfg, n_pages=32, page_size=16, n_slabs=6)
    rng = np.random.default_rng(13)
    pref, sref = {}, {}                        # shadow refcounts per kind
    for _ in range(500):
        r = rng.random()
        live_p, live_s = sorted(pref), sorted(sref)
        if r < 0.2:
            got = pool.alloc(int(rng.integers(1, 4)))
            if got is not None:
                for pg in got:
                    pref[pg] = 1
        elif live_p and r < 0.35:
            pg = live_p[rng.integers(0, len(live_p))]
            pool.incref([pg])
            pref[pg] += 1
        elif live_p and r < 0.55:
            pg = live_p[rng.integers(0, len(live_p))]
            pool.free([pg])
            pref[pg] -= 1
            if pref[pg] == 0:
                del pref[pg]
        elif r < 0.7:
            sl = pool.alloc_slab()
            if sl is not None:
                sref[sl] = 1
        elif live_s and r < 0.85:
            sl = live_s[rng.integers(0, len(live_s))]
            pool.incref_slab(sl)
            sref[sl] += 1
        elif live_s:
            sl = live_s[rng.integers(0, len(live_s))]
            pool.free_slab(sl)
            sref[sl] -= 1
            if sref[sl] == 0:
                del sref[sl]
        assert pool._allocated == set(pref)
        assert all(pool.refcount(pg) == n for pg, n in pref.items())
        assert pool.free_pages + pool.used_pages == pool.n_pages
        assert pool._slab_allocated == set(sref)
        assert all(pool.slab_refcount(sl) == n for sl, n in sref.items())
        assert pool.free_slabs + pool.used_slabs == pool.n_slabs
    for pg, n in list(pref.items()):
        for _ in range(n):
            pool.free([pg])
    for sl, n in list(sref.items()):
        for _ in range(n):
            pool.free_slab(sl)
    assert pool.used_pages == 0 and pool.used_slabs == 0
    assert pool.free_pages == pool.n_pages
    assert pool.free_slabs == pool.n_slabs
    with pytest.raises(AssertionError):
        pool.free_slab(1)                      # double free still fires


def _random_cache_q(L, s, kh, dh):
    out = {}
    for key, cols in (("k_codes", dh), ("v_codes", dh),
                      ("k_scale", 1), ("v_scale", 1)):
        x = RNG.integers(0, 255, (L, 1, s, kh, cols))
        out[key] = (jnp.asarray(x).astype(jnp.uint8) if "codes" in key
                    else jnp.asarray(x).astype(jnp.bfloat16))
    return out


def test_write_chunk_matches_write_prefill():
    """Writing a prefill chunk by chunk (the chunked-prefill data path)
    lands bit-identical pool state to one whole-prefix write_prefill."""
    L, kh, dh = CFG.n_layers, CFG.n_kv_heads, CFG.resolved_head_dim
    cache_q = _random_cache_q(L, 32, kh, dh)
    whole = PagedKVPool(CFG, n_pages=6, page_size=8)
    pages = whole.alloc(4)
    whole.write_prefill(cache_q, pages)
    chunked = PagedKVPool(CFG, n_pages=6, page_size=8)
    pages_c = chunked.alloc(4)
    assert pages_c == pages                      # same LIFO order
    for start in (0, 16):                        # two 16-token chunks
        chunk = {k: v[:, :, start:start + 16] for k, v in cache_q.items()}
        chunked.write_chunk(chunk, pages_c, start)
    for key in cache_q:
        np.testing.assert_array_equal(
            np.asarray(getattr(whole, key)), np.asarray(getattr(chunked, key)))


def test_write_chunk_drops_pad_pages_past_allocation():
    """A final chunk padded past the live prefix only writes the pages
    the request owns; the pad blocks are dropped, not scattered into
    somebody else's pages."""
    L, kh, dh = CFG.n_layers, CFG.n_kv_heads, CFG.resolved_head_dim
    pool = PagedKVPool(CFG, n_pages=6, page_size=8)
    other = pool.alloc(3)                        # a neighbor's pages
    mine = pool.alloc(2)
    before = {k: np.asarray(getattr(pool, k)) for k in
              ("k_codes", "v_codes", "k_scale", "v_scale")}
    chunk = _random_cache_q(L, 16, kh, dh)       # 2 blocks...
    pool.write_chunk(chunk, mine, 8)             # ...but only 1 page left
    for key in before:
        now = np.asarray(getattr(pool, key))
        np.testing.assert_array_equal(            # the owned page got data
            now[:, mine[1]], np.asarray(chunk[key][:, 0, :8]))
        np.testing.assert_array_equal(            # nobody else was touched
            now[:, other], before[key][:, other])
        np.testing.assert_array_equal(now[:, 0], before[key][:, 0])


def test_paged_kv_bytes_scale_with_live_pages():
    """The modeled per-step KV bytes depend on live positions only --
    there is no max_len anywhere in the paged model."""
    b1 = paged_kv_bytes_per_step(CFG, [7, 40], 16)
    b2 = paged_kv_bytes_per_step(CFG, [7, 40, 40], 16)
    assert b2 > b1
    # doubling a request's live length doubles its share
    lo = paged_kv_bytes_per_step(CFG, [15], 16)
    hi = paged_kv_bytes_per_step(CFG, [31], 16)
    assert hi == 2 * lo


# ---------------------------------------------------------------------------
# ragged (left-padded) static serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True])
def test_ragged_generate_matches_per_request(quantized_kv):
    """A LEFT-padded mixed-length batch generates exactly what
    per-request calls would: pads are masked out of attention and RoPE
    starts at each request's first real token."""
    params = T.lm_init(jax.random.PRNGKey(0), CFG)
    lens = [3, 7, 5, 10]
    s0 = max(lens)
    prompts = [RNG.integers(0, CFG.vocab, (n,)).astype(np.int32)
               for n in lens]
    toks = np.zeros((len(lens), s0), np.int32)
    for i, p in enumerate(prompts):
        toks[i, s0 - p.size:] = p
    eng = ServeEngine(CFG, params, max_len=32, quantized_kv=quantized_kv)
    ragged = eng.generate(jnp.asarray(toks), steps=6,
                          lengths=np.asarray(lens))
    for i, p in enumerate(prompts):
        want = eng.generate(jnp.asarray(p)[None], steps=6)[0]
        np.testing.assert_array_equal(ragged[i, s0 - p.size:], want)


def test_ragged_rejects_stateful_family():
    cfg = get_config("rwkv6-1.6b").reduced()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=16)
    with pytest.raises(ValueError):
        eng.generate(jnp.zeros((2, 4), jnp.int32), steps=2, lengths=[2, 4])
