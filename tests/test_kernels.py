"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True on CPU), quire bit-exactness, block gating."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # skips property tests w/o hypothesis

from repro.core import formats as F
from repro.core import quire as Q
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("spec", [F.FP4, F.POSIT4, F.POSIT8, F.POSIT16],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("shape", [(17, 300, 200), (1, 64, 64),
                                   (130, 1030, 250)])
def test_rmmec_matmul_vs_ref(spec, shape):
    m, k, n = shape
    w = jnp.asarray(RNG.normal(size=(k, n)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    t = ops.pack_tensor(spec, w)
    out_k = ops.packed_matmul(x, t)
    out_r = ref.rmmec_matmul_ref(x, t.words, t.scales, spec,
                                 t.scales.shape[1])[:, :n]
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=3e-6, atol=1e-4)
    # and against dense x @ dequant(w)
    out_d = x @ ops.unpack_tensor(t)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               rtol=3e-6, atol=1e-4)


def test_rmmec_bf16_fast_path():
    w = jnp.asarray(RNG.normal(size=(256, 128)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(8, 256)).astype(np.float32))
    t = ops.pack_tensor(F.POSIT8, w)
    out_b = ops.packed_matmul(x.astype(jnp.bfloat16), t)
    out_f = ops.packed_matmul(x, t)
    rel = float(jnp.max(jnp.abs(out_b - out_f))) / \
        float(jnp.max(jnp.abs(out_f)))
    assert rel < 2e-2  # bf16-level agreement


def test_rmmec_power_gating_zero_blocks():
    """All-zero weight blocks are gated; result identical to the oracle.
    (fp4 K-blocks are 1024 wide -- zero the second full block.)"""
    w = np.zeros((2048, 256), np.float32)
    w[:1024, :] = RNG.normal(size=(1024, 256))  # second K block all-zero
    t = ops.pack_tensor(F.FP4, jnp.asarray(w))
    assert int(np.asarray(t.mask).sum()) < t.mask.size  # some blocks gated
    x = jnp.asarray(RNG.normal(size=(8, 2048)).astype(np.float32))
    out = ops.packed_matmul(x, t)
    out_r = ref.rmmec_matmul_ref(x, t.words, t.scales, F.FP4,
                                 t.scales.shape[1])[:, :256]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=3e-6, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 900), st.integers(0, 2**31 - 1))
def test_quire_dot_property(b, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(b, k))
    bb = rng.integers(0, 256, size=(b, k))
    got = np.asarray(ops.quire_dot(jnp.asarray(a), jnp.asarray(bb)))
    want = ref.quire_dot_ref(a, bb)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


def test_quire_dot_beats_f32_accumulation():
    """Construct a cancellation case where naive f32 accumulation rounds
    but the quire is exact (the paper's reason for the quire)."""
    big = int(F.encode(F.POSIT8, jnp.asarray([64.0]))[0])
    one = int(F.encode(F.POSIT8, jnp.asarray([1.0 / 64]))[0])
    neg = int(F.encode(F.POSIT8, jnp.asarray([-64.0]))[0])
    # 64*64 + (1/64 * 1/64)*k + (-64*64): exact = k/4096
    a = np.array([[big] + [one] * 512 + [neg]])
    b = np.array([[big] + [one] * 512 + [neg]])
    got = float(ops.quire_dot(jnp.asarray(a), jnp.asarray(b))[0])
    want = Q.quire_dot_exact(F.POSIT8, a[0], b[0])
    assert got == pytest.approx(want, rel=1e-7)
    # naive f32 running sum in the same order loses the tiny terms
    vals = F.code_values(F.POSIT8)
    acc = np.float32(0)
    for x, y in zip(vals[a[0]], vals[b[0]]):
        acc = np.float32(acc + np.float32(x * y))
    assert got == want and abs(float(acc) - want) >= 0  # quire == exact


@pytest.mark.parametrize("spec", [F.FP4, F.POSIT8], ids=lambda s: s.name)
def test_dequant_kernel(spec):
    w = jnp.asarray(RNG.normal(size=(256, 512)).astype(np.float32))
    t = ops.pack_tensor(spec, w)
    d = ops.dequant(t)
    np.testing.assert_array_equal(np.asarray(d),
                                  np.asarray(ops.unpack_tensor(t)))


def test_packed_tensor_memory_footprint():
    """Packed bytes ~= logical_bits/8 (the HBM saving is real)."""
    w = jnp.asarray(RNG.normal(size=(1024, 1024)).astype(np.float32))
    t4 = ops.pack_tensor(F.FP4, w)
    t8 = ops.pack_tensor(F.POSIT8, w)
    dense = 1024 * 1024 * 4
    assert t4.words.size * 4 <= dense // 8 * 1.01
    assert t8.words.size * 4 <= dense // 4 * 1.01
