"""Checkpoint substrate: atomicity, retention, corruption detection,
async save, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))},
            "b": jnp.arange(5, dtype=jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, extra={"data": {"step": 3}})
    t2, extra, step = restore_checkpoint(str(tmp_path), t)
    assert step == 3 and extra["data"]["step"] == 3
    np.testing.assert_array_equal(np.asarray(t2["a"]["w"]),
                                  np.asarray(t["a"]["w"]))


def test_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_atomic_no_tmp_left(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_corruption_detected(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    # truncate one leaf file
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    full = os.path.join(path, fn)
    arr = np.load(full)
    np.save(full, arr[:2])
    with pytest.raises((IOError, KeyError, ValueError)):
        restore_checkpoint(str(tmp_path), t)


def test_async_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree()
    mgr.save(1, t)
    mgr.save(2, t)  # waits for the first
    mgr.wait()
    assert mgr.latest_step() == 2


def test_elastic_restore_new_mesh(tmp_path):
    """Save unsharded, restore onto an explicit sharding (mesh of 1 here;
    the path exercises device_put-with-sharding, which is what a N->M
    chip restore uses)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:  # newer jax: explicit Auto axis types
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):  # jax<=0.4.x has neither
        mesh = jax.make_mesh((1,), ("data",))
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    sh = {"a": {"w": NamedSharding(mesh, P(None, None))},
          "b": NamedSharding(mesh, P())}
    t2, _, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert t2["a"]["w"].sharding == sh["a"]["w"]
