"""Continuous-batching plane: scheduler state machine (admission order,
preemption + resume, retire-on-EOS) and ContinuousEngine end-to-end
token parity against the static per-request oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import ContinuousEngine, PagedKVPool, Scheduler, ServeEngine
from repro.serve.scheduler import FINISHED, PREFILLING, RUNNING, WAITING

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(0)


def _params():
    return T.lm_init(jax.random.PRNGKey(0), CFG)


def _sched(n_pages=8, page_size=4, max_batch=4):
    return Scheduler(PagedKVPool(CFG, n_pages, page_size), max_batch)


def _prompt(n):
    return np.arange(1, n + 1, dtype=np.int32)


def _page_in(s, req):
    """Drive a PREFILLING request's page side to completion (what the
    engine's chunk loop does, minus the model)."""
    assert s.ensure_prefill_capacity(req, len(req.prefix))
    req.prefilled = len(req.prefix)
    s.prefill_complete(req)


# ---------------------------------------------------------------------------
# scheduler unit tests (no model involved)
# ---------------------------------------------------------------------------

def test_admission_fifo_order_and_claim_gating():
    s = _sched(n_pages=5, page_size=4, max_batch=8)
    r0 = s.submit(_prompt(7), 4)    # claims pages_for(8) = 2
    r1 = s.submit(_prompt(7), 4)    # 2
    r2 = s.submit(_prompt(3), 2)    # 1
    r3 = s.submit(_prompt(3), 2)    # 1, but the claims sum to the pool
    admitted = s.admit()
    assert [r.rid for r in admitted] == [r0, r1, r2]
    assert all(r.status == PREFILLING for r in admitted)
    # pages are allocated lazily per chunk, NOT at admission -- but the
    # admitted requests' outstanding claims still gate the queue head
    assert s.pool.free_pages == 5
    assert [r.rid for r in s.waiting] == [r3]       # head-of-line gated
    assert s.admit() == []                          # claims unchanged
    for r in admitted:                              # prefill allocates
        _page_in(s, r)
    assert s.pool.free_pages == 0                   # 2 + 2 + 1
    # retiring returns pages and the next admit picks up the queue head
    s.retire(s.running[0])
    assert [r.rid for r in s.admit()] == [r3]


def test_admission_strict_fifo_blocks_on_big_head():
    """A too-big head must NOT be overtaken by a small later request."""
    s = _sched(n_pages=4, page_size=4, max_batch=8)
    holder = s.submit(_prompt(6), 2)  # claims 2 pages -> 2 unclaimed
    (h,) = s.admit()
    _page_in(s, h)
    big = s.submit(_prompt(9), 3)     # needs 3 free pages now, has 2
    small = s.submit(_prompt(2), 1)   # would fit, but FIFO
    assert s.admit() == []
    assert [r.rid for r in s.waiting] == [big, small]


def test_submit_rejects_unpageable_request():
    s = _sched(n_pages=2, page_size=4)
    with pytest.raises(ValueError):
        s.submit(_prompt(10), 10)    # 5 pages > pool capacity


def test_preemption_frees_youngest_and_requeues_front():
    s = _sched(n_pages=4, page_size=4, max_batch=4)
    r0 = s.submit(_prompt(6), 8)     # 2 pages
    r1 = s.submit(_prompt(6), 8)     # 2 pages
    a, b = s.admit()
    _page_in(s, a)
    _page_in(s, b)
    a.generated, b.generated = [9], [9]          # decoding
    # a's next write crosses into page 2 (position 6 -> idx 1 owned);
    # simulate growth to the boundary
    a.generated = [9, 9, 9]                      # position 8 -> page idx 2
    assert s.ensure_capacity(a) is True          # pool dry -> b preempted
    assert b.status == WAITING and b.pages == [] and b.preemptions == 1
    assert s.waiting[0] is b                     # requeued at the FRONT
    assert b.generated == [9]                    # resume keeps its tokens
    assert b.prefilled == 0                      # resume re-prefills
    assert s.wasted_prefill_tokens == 7          # b's prefix KV tossed
    assert a.status == RUNNING and len(a.pages) == 3
    # the victim re-admits once pages free up again
    s.retire(a)
    assert [r.rid for r in s.admit()] == [r1]
    assert s.running[0].rid == r1 and r0 in s.finished


def test_preemption_drops_half_prefilled_request():
    """A PREFILLING victim is preemptable mid-prefill: its pages return,
    its chunk cursor resets, and the waste is counted."""
    s = _sched(n_pages=3, page_size=4, max_batch=4)
    r0 = s.submit(_prompt(6), 4)                 # claims 2
    (a,) = s.admit()
    _page_in(s, a)
    a.generated = [9]
    r1 = s.submit(_prompt(9), 3)                 # claims 3 > 1 free...
    assert s.admit() == []
    s.retire(a)                                  # ...until a retires
    (b,) = s.admit()
    assert s.ensure_prefill_capacity(b, 4)       # chunk 1 paged in
    b.prefilled = 4
    assert b.status == PREFILLING and len(b.pages) == 1
    s.preempt(b)
    assert b.status == WAITING and b.pages == [] and b.prefilled == 0
    assert s.prefill_preemptions == 1
    assert s.wasted_prefill_tokens == 4          # one chunk thrown away
    assert s.pool.used_pages == 0


def test_preemption_self_when_youngest():
    s = _sched(n_pages=2, page_size=4, max_batch=4)
    r0 = s.submit(_prompt(6), 2)
    (a,) = s.admit()
    _page_in(s, a)
    a.generated = [9, 9, 9]                      # needs a 3rd page, pool dry
    assert s.ensure_capacity(a) is False
    assert a.status == WAITING and s.running == [] and s.pool.free_pages == 2


def test_retire_on_eos_returns_pages():
    s = _sched(n_pages=4, page_size=4)
    rid = s.submit(_prompt(3), 8, eos_id=7)
    (req,) = s.admit()
    _page_in(s, req)
    used = s.pool.used_pages
    assert used > 0
    req.generated = [5, 7]                       # EOS sampled
    assert req.done
    s.retire(req)
    assert req.status == FINISHED and s.pool.used_pages == 0
    assert s.finished[rid].output.tolist() == [1, 2, 3, 5, 7]


def test_request_done_on_budget():
    s = _sched()
    rid = s.submit(_prompt(2), 2)
    (req,) = s.admit()
    req.generated = [1]
    assert not req.done
    req.generated = [1, 2]
    assert req.done


# ---------------------------------------------------------------------------
# ContinuousEngine end-to-end
# ---------------------------------------------------------------------------

def test_continuous_matches_static_per_request():
    """>= 8 overlapping requests with different prompt/generation
    lengths match per-request static generate token for token at
    temperature 0 (page size == the static engine's KV block, so both
    paths share one online-softmax accumulation order)."""
    params = _params()
    reqs = [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in [(3, 6), (5, 12), (8, 4), (10, 20), (4, 9),
                           (7, 15), (6, 5), (9, 11)]]
    eng = ContinuousEngine(CFG, params, n_pages=40, page_size=16,
                           max_batch=8, max_len=48)
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    static = ServeEngine(CFG, params, max_len=48, quantized_kv=True)
    for rid, (p, g) in zip(rids, reqs):
        want = static.generate(jnp.asarray(p)[None], steps=g)[0]
        np.testing.assert_array_equal(out[rid], want)
    assert eng.pool.used_pages == 0              # everything retired


def test_continuous_staggered_arrivals_join_and_retire():
    """Requests submitted mid-flight join the running batch next step
    and parity with the static oracle still holds."""
    params = _params()
    eng = ContinuousEngine(CFG, params, n_pages=40, page_size=16,
                           max_batch=4, max_len=48)
    static = ServeEngine(CFG, params, max_len=48, quantized_kv=True)
    early = [(RNG.integers(0, CFG.vocab, (4,)).astype(np.int32), 12),
             (RNG.integers(0, CFG.vocab, (6,)).astype(np.int32), 10)]
    late = [(RNG.integers(0, CFG.vocab, (9,)).astype(np.int32), 6),
            (RNG.integers(0, CFG.vocab, (3,)).astype(np.int32), 8)]
    rids = [eng.submit(p, g) for p, g in early]
    for _ in range(3):
        eng.step()
    assert len(eng.scheduler.running) == 2       # mid-flight
    rids += [eng.submit(p, g) for p, g in late]
    out = eng.run()
    for rid, (p, g) in zip(rids, early + late):
        want = static.generate(jnp.asarray(p)[None], steps=g)[0]
        np.testing.assert_array_equal(out[rid], want)


def test_continuous_eos_retires_early():
    """A request whose sampled token hits eos_id retires before its
    budget and its pages return to the pool for the others."""
    params = _params()
    probe = ContinuousEngine(CFG, params, n_pages=12, page_size=16,
                             max_batch=2, max_len=48)
    p = RNG.integers(0, CFG.vocab, (5,)).astype(np.int32)
    rid0 = probe.submit(p, 10)
    gen = probe.run()[rid0][p.size:]
    # pick an "EOS" at its FIRST occurrence in the stream (tiny models
    # repeat tokens; an earlier duplicate would retire sooner), as late
    # as possible while still strictly before the budget
    k = max(i for i, v in enumerate(gen)
            if v not in gen[:i] and i < gen.size - 1)
    eos = int(gen[k])
    eng = ContinuousEngine(CFG, params, n_pages=12, page_size=16,
                           max_batch=2, max_len=48, eos_id=eos)
    rid = eng.submit(p, 10)
    out = eng.run()[rid]
    assert out.size == p.size + k + 1 and out[-1] == eos
    assert out.size < p.size + gen.size          # really retired early
    assert eng.pool.used_pages == 0


def test_continuous_preemption_resume_deterministic():
    """A starved pool forces preemption; the run stays deterministic,
    pages all return, non-preempted requests are bit-exact against an
    ample pool, and preempted ones agree on the overwhelming majority
    of tokens (resume re-prefills the prefix, whose logits differ from
    incremental decode only in accumulation order)."""
    params = _params()
    reqs = [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in [(10, 20), (12, 18), (9, 22), (11, 16)]]

    def run(n_pages):
        eng = ContinuousEngine(CFG, params, n_pages=n_pages, page_size=8,
                               max_batch=4, max_len=40)
        rids = [eng.submit(p, g) for p, g in reqs]
        out = eng.run()
        return ([out[r] for r in rids],
                [eng.scheduler.finished[r].preemptions for r in rids],
                eng)

    ample, pre_a, _ = run(32)
    starved, pre_s, eng = run(7)
    starved2, _, _ = run(7)
    assert sum(pre_a) == 0 and sum(pre_s) > 0    # starvation really hit
    assert eng.pool.used_pages == 0              # no page leaked
    for a, b in zip(starved, starved2):          # deterministic
        np.testing.assert_array_equal(a, b)
    agree = total = 0
    for out_a, out_s, n_pre in zip(ample, starved, pre_s):
        if n_pre == 0:
            np.testing.assert_array_equal(out_a, out_s)
        agree += int((out_a == out_s).sum())
        total += out_a.size
    assert agree / total > 0.9, (agree, total)


def test_continuous_flash_impl_matches_blocked():
    """decode_impl='flash' drives the paged Pallas kernel (interpret on
    CPU) and reproduces the XLA path's tokens."""
    cfg = dataclasses.replace(CFG, decode_impl="flash")
    params = _params()
    reqs = [(RNG.integers(0, CFG.vocab, (4,)).astype(np.int32), 6),
            (RNG.integers(0, CFG.vocab, (7,)).astype(np.int32), 5)]

    def run(c):
        eng = ContinuousEngine(c, params, n_pages=12, page_size=16,
                               max_batch=2, max_len=32)
        rids = [eng.submit(p, g) for p, g in reqs]
        out = eng.run()
        return [out[r] for r in rids]

    for a, b in zip(run(cfg), run(CFG)):
        np.testing.assert_array_equal(a, b)


def test_no_same_step_admit_then_preempt_thrash():
    """REGRESSION (PR 4): admission must come AFTER capacity for the
    running batch.  The PR 3 step() admitted (and fully prefilled) a
    newcomer first; when a running request needed its next page in the
    same step, the newcomer -- youngest -- was preempted and its whole
    prefill thrown away, every step while pool pressure lasted.  Now a
    just-admitted request is never preempted in the same step and no
    prefill work is wasted in this scenario."""
    params = _params()
    eng = ContinuousEngine(CFG, params, n_pages=4, page_size=16,
                           max_batch=4, max_len=64)
    p0 = RNG.integers(0, CFG.vocab, (14,)).astype(np.int32)
    p1 = RNG.integers(0, CFG.vocab, (17,)).astype(np.int32)
    r0 = eng.submit(p0, 20)          # grows to 3 pages over its life
    for _ in range(18):              # drive to the page-boundary step:
        eng.step()                   # r0 is about to take a 3rd page
    assert eng.pool.free_pages == 2
    r1 = eng.submit(p1, 4)           # needs 2 pages -- exactly what's free
    seen = 0
    while eng.scheduler.has_work:
        eng.step()
        new_preempted = set(eng.scheduler.preempted_log[seen:])
        seen = len(eng.scheduler.preempted_log)
        # the regression: admitted and preempted in one step
        assert not (set(eng.last_admitted) & new_preempted)
    # capacity-first defers r1 instead of thrashing it: zero preemptions,
    # zero wasted prefill work, and both requests complete
    assert eng.scheduler.preemption_count == 0
    assert eng.scheduler.wasted_prefill_tokens == 0
    assert {r0, r1} <= set(eng.scheduler.finished)
    assert eng.pool.used_pages == 0


def test_chunked_prefill_matches_static():
    """Chunked paged prefill (the tentpole): multi-chunk prompts match
    per-request static generate token for token at temperature 0 --
    the bf16 carry makes chunk logits bitwise those of a monolithic
    prefill."""
    params = _params()
    reqs = [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in [(24, 6), (17, 8), (33, 5), (9, 10), (40, 4)]]
    eng = ContinuousEngine(CFG, params, n_pages=40, page_size=16,
                           max_batch=8, max_len=48,
                           prefill_chunk_tokens=16)
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    static = ServeEngine(CFG, params, max_len=48, quantized_kv=True)
    for rid, (p, g) in zip(rids, reqs):
        want = static.generate(jnp.asarray(p)[None], steps=g)[0]
        np.testing.assert_array_equal(out[rid], want)
    assert eng.pool.used_pages == 0


def test_chunk_budget_bounds_prefill_per_step():
    """One engine step processes at most prefill_chunk_tokens prefill
    tokens: a 40-token prompt takes ceil(40/16) chunk steps, decoding
    only once the final chunk lands."""
    params = _params()
    eng = ContinuousEngine(CFG, params, n_pages=8, page_size=16,
                           max_batch=2, max_len=48,
                           prefill_chunk_tokens=16)
    eng.submit(RNG.integers(0, CFG.vocab, (40,)).astype(np.int32), 3)
    assert eng.step() == 0           # chunk 1: nothing decoded
    (req,) = eng.scheduler.running
    assert req.status == PREFILLING and req.prefilled == 16
    assert eng.step() == 0           # chunk 2
    assert req.prefilled == 32
    assert eng.step() == 1           # final chunk + first decode
    assert req.prefilled == 40 and req.status == RUNNING


def test_chunked_mid_prefill_preemption_exact():
    """A starved pool preempts a request MID-PREFILL (chunk cursor
    reset, pages returned); because the victim had not started decoding
    and the non-victim is never preempted, resume is EXACTLY the
    monolithic logits -- full static parity survives the preemption.
    Also deterministic across runs."""
    params = _params()
    p0 = RNG.integers(0, CFG.vocab, (15,)).astype(np.int32)
    p1 = RNG.integers(0, CFG.vocab, (40,)).astype(np.int32)

    def run():
        eng = ContinuousEngine(CFG, params, n_pages=4, page_size=16,
                               max_batch=4, max_len=48,
                               prefill_chunk_tokens=16)
        rids = [eng.submit(p0, 20), eng.submit(p1, 4)]
        out = eng.run()
        return [out[r] for r in rids], eng

    (a0, a1), eng = run()
    (b0, b1), _ = run()
    assert eng.scheduler.prefill_preemptions >= 1   # really hit mid-prefill
    assert eng.scheduler.wasted_prefill_tokens > 0
    assert eng.pool.used_pages == 0
    np.testing.assert_array_equal(a0, b0)           # deterministic
    np.testing.assert_array_equal(a1, b1)
    static = ServeEngine(CFG, params, max_len=48, quantized_kv=True)
    np.testing.assert_array_equal(
        a0, static.generate(jnp.asarray(p0)[None], steps=20)[0])
    np.testing.assert_array_equal(
        a1, static.generate(jnp.asarray(p1)[None], steps=4)[0])


def test_chunked_prefill_pages_context():
    """prefill_context='pages' re-reads the prefix from its posit8 pages
    (zero extra residency): deterministic, drains the pool, and stays
    within quantization error of the exact carry path -- and the fused
    paged-prefill kernel (decode_impl='flash', interpret on CPU)
    reproduces the XLA fallback's tokens."""
    params = _params()
    reqs = [(RNG.integers(0, CFG.vocab, (33,)).astype(np.int32), 6),
            (RNG.integers(0, CFG.vocab, (7,)).astype(np.int32), 8)]

    def run(ctx, cfg=CFG):
        eng = ContinuousEngine(cfg, params, n_pages=12, page_size=16,
                               max_batch=2, max_len=48,
                               prefill_chunk_tokens=16,
                               prefill_context=ctx)
        rids = [eng.submit(p, g) for p, g in reqs]
        out = eng.run()
        assert eng.pool.used_pages == 0
        return [out[r] for r in rids]

    pages = run("pages")
    for a, b in zip(pages, run("pages")):            # deterministic
        np.testing.assert_array_equal(a, b)
    carry = run("carry")
    for (p, _), a, b in zip(reqs, pages, carry):
        assert a.shape == b.shape
        np.testing.assert_array_equal(a[:p.size], b[:p.size])  # prompt kept
    # the dequantized context may flip a greedy argmax (after which the
    # streams legitimately diverge), but most tokens still agree
    agree = sum(int((a == b).sum()) for a, b in zip(pages, carry))
    total = sum(a.size for a in pages)
    assert agree / total > 0.7, (agree, total)
    flash_cfg = dataclasses.replace(CFG, decode_impl="flash")
    for a, b in zip(pages, run("pages", flash_cfg)):
        np.testing.assert_array_equal(a, b)


def test_continuous_rejects_oversized_and_stateful():
    params = _params()
    eng = ContinuousEngine(CFG, params, n_pages=8, page_size=16,
                           max_batch=2, max_len=32)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(30, np.int32), 10)   # 40 slots > max_len
    ssm_cfg = get_config("rwkv6-1.6b").reduced()
    ssm_params = T.lm_init(jax.random.PRNGKey(1), ssm_cfg)
    # stateful families now serve -- but only on the carry prefill
    # context; the paged context re-reads the prefix through the page
    # table, which recurrent state never lands in
    with pytest.raises(ValueError, match="recurrent state"):
        ContinuousEngine(ssm_cfg, ssm_params, n_pages=8, page_size=16,
                         max_batch=2, max_len=32,
                         prefill_context="pages")
    eng_s = ContinuousEngine(ssm_cfg, ssm_params, n_pages=8, page_size=16,
                             max_batch=2, max_len=32)
    assert eng_s.pool.has_state and not eng_s.pool.has_kv
    assert eng_s.pool.n_slabs == 2               # one slab per batch slot
