"""Optional-hypothesis shim: property tests run when hypothesis is
installed and are individually skipped (never a collection error) when it
is not.  Usage: ``from _hyp import given, settings, st``."""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover - exercised without the dep
    class _Strategies:
        """Stands in for ``hypothesis.strategies`` at decoration time;
        the decorated tests are skipped, so strategy values never run."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f
