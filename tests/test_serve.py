"""Serving plane tests: packed weights, KV quantization, generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import PrecisionPolicy
from repro.models import transformer as T
from repro.models import zoo
from repro.serve.engine import ServeEngine, build_serve_step

CFG = get_config("qwen2-0.5b").reduced()


def _params():
    return T.lm_init(jax.random.PRNGKey(0), CFG)


def test_packed_params_close_to_dense():
    params = _params()
    packed = zoo.pack_params(params, PrecisionPolicy.uniform("posit8_0"))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    l_dense, _, _ = zoo.apply_model(params, batch, CFG)
    l_pack, _, _ = zoo.apply_model(packed, batch, CFG)
    pd = jax.nn.softmax(l_dense.astype(jnp.float32), -1)
    pp = jax.nn.softmax(l_pack.astype(jnp.float32), -1)
    # posit8 weights keep the distribution close
    assert float(jnp.max(jnp.abs(pd - pp))) < 0.12


def test_decode_matches_prefill_continuation():
    """Greedy continuation via decode must match teacher-forced prefill
    logits at each position."""
    params = _params()
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, CFG.vocab, (2, 12)), jnp.int32)
    logits_all, cache, _ = zoo.apply_model(
        params, {"tokens": toks}, CFG, mode="prefill")
    # now decode token 12 using the prefill cache, compare against a
    # full forward over 13 tokens
    step = build_serve_step(CFG)
    # grow cache to length 13+
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == 12:
            pw = [(0, 0)] * x.ndim
            pw[2] = (0, 8)
            return jnp.pad(x, pw)
        return x
    cache = jax.tree.map(pad, cache)
    nxt = jnp.argmax(logits_all[:, -1:], -1).astype(jnp.int32)
    logits_dec, _ = step(params, nxt, cache, jnp.int32(12))
    full = jnp.concatenate([toks, nxt], 1)
    logits_full, _, _ = zoo.apply_model(params, {"tokens": full}, CFG)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=0.15, atol=0.15)


def test_quantized_kv_close():
    """Posit8 KV cache decodes to near-identical attention output."""
    params = _params()
    B = 2
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, CFG.vocab, (B, 1)), jnp.int32)
    cache_f = T.init_cache(CFG, B, 32, quantized_kv=False)
    cache_q = T.init_cache(CFG, B, 32, quantized_kv=True)
    lf, _ = zoo.decode_model(params, toks, CFG, cache_f, jnp.int32(0))
    lq, _ = zoo.decode_model(params, toks, CFG, cache_q, jnp.int32(0))
    pf = jax.nn.softmax(lf.astype(jnp.float32), -1)
    pq = jax.nn.softmax(lq.astype(jnp.float32), -1)
    assert float(jnp.max(jnp.abs(pf - pq))) < 0.05


def test_engine_generates():
    params = _params()
    eng = ServeEngine(CFG, params, max_len=64)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, CFG.vocab, (2, 8)), jnp.int32)
    out = eng.generate(toks, steps=5)
    assert out.shape == (2, 13)
    assert np.isfinite(out).all()


def test_engine_packed_policy():
    params = _params()
    eng = ServeEngine(CFG, params, max_len=32,
                      policy=PrecisionPolicy.paper_mixed())
    toks = jnp.zeros((1, 4), jnp.int32)
    out = eng.generate(toks, steps=3)
    assert out.shape == (1, 7)


def test_pad_cache_pads_scales_with_one():
    """_pad_cache must pad k_scale/v_scale with the neutral scale 1.0
    (the paged pool's convention), not jnp.pad's default 0.0: a zero
    po2 scale silently dequantizes any code written into a padded slot
    to 0, and only the positional mask was hiding it."""
    params = _params()
    eng = ServeEngine(CFG, params, max_len=32, quantized_kv=True)
    cache = T.init_cache(CFG, 2, 12, quantized_kv=True)
    padded = eng._pad_cache(cache, 2)

    def leaves(node, path=""):
        if isinstance(node, dict):
            for k, v in node.items():
                yield from leaves(v, k)
        else:
            yield path, node

    seen_scale = 0
    for key, x in leaves(padded):
        if key in ("k_scale", "v_scale"):
            assert x.shape[2] == 32
            tail = np.asarray(x[:, :, 12:], np.float32)
            np.testing.assert_array_equal(tail, np.ones_like(tail))
            seen_scale += 1
        elif key in ("k_codes", "v_codes"):
            assert not np.asarray(x[:, :, 12:]).any()
    assert seen_scale == 2
