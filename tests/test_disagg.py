"""Disaggregated prefill/decode serving (PR 7): posit8 page handoff.

The pinned invariant extends across the split: temperature-0 output of
``DisaggEngine`` is token-for-token identical to the interleaved
``ContinuousEngine`` AND the static per-request ``ServeEngine`` oracle
-- through decode-pool pressure (bounces), prefix-cache hits and
channel backpressure -- and the handoff payload is bitwise the pool's
posit8 codes + scales (``page_handoff_bytes`` models its size
exactly)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (ContinuousEngine, DisaggEngine, PagedKVPool,
                         PageHandoffChannel, ServeEngine,
                         page_handoff_bytes)
from repro.serve.paged_kv import _POOL_KEYS

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(11)
PARAMS = T.lm_init(jax.random.PRNGKey(0), CFG)


def _reqs(spec):
    return [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in spec]


def _run_disagg(reqs, **kw):
    kw.setdefault("prefill_pages", 40)
    kw.setdefault("decode_pages", 40)
    kw.setdefault("page_size", 16)
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_len", 48)
    eng = DisaggEngine(CFG, PARAMS, **kw)
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    return [out[r] for r in rids], eng


def _run_interleaved(reqs, n_pages=40, **kw):
    kw.setdefault("page_size", 16)
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_len", 48)
    eng = ContinuousEngine(CFG, PARAMS, n_pages=n_pages, **kw)
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    return [out[r] for r in rids], eng


# ---------------------------------------------------------------------------
# page export/import: the handoff is bitwise
# ---------------------------------------------------------------------------

def test_export_import_roundtrip_bitwise():
    """Exported pages scatter bitwise into another pool at DIFFERENT
    page ids; the payload is a functional gather (stays valid after the
    source pages are freed) and the destination pages come out at
    refcount 1 while the source refcounts are untouched."""
    src = PagedKVPool(CFG, 8, 16)
    dst = PagedKVPool(CFG, 8, 16)
    rng = np.random.default_rng(3)
    for key in _POOL_KEYS:
        leaf = getattr(src, key)
        if leaf.dtype == jnp.uint8:
            fill = rng.integers(0, 256, leaf.shape).astype(np.uint8)
        else:
            fill = (2.0 ** rng.integers(-4, 5, leaf.shape)).astype(
                np.float32)
        setattr(src, key, jnp.asarray(fill, leaf.dtype))
    pages = src.alloc(3)
    payload = src.export_pages(pages)
    # destination at different ids, deliberately out of order
    got = dst.alloc(4)
    target = [got[2], got[0], got[3]]
    dst.import_pages(payload, target)
    for key in _POOL_KEYS:
        want = np.asarray(getattr(src, key))[:, pages]
        have = np.asarray(getattr(dst, key))[:, target]
        np.testing.assert_array_equal(have, want, err_msg=key)
    assert all(dst.refcount(pg) == 1 for pg in got)
    assert all(src.refcount(pg) == 1 for pg in pages)
    # functional gather: freeing the source pages must not corrupt an
    # already-exported payload
    snap = {key: np.asarray(val) for key, val in payload.items()}
    src.free(pages)
    for key in _POOL_KEYS:
        np.testing.assert_array_equal(np.asarray(payload[key]), snap[key])
    assert src.used_pages == 0


def test_handoff_bytes_model():
    """The measured payload size is exactly the per-page posit8 model:
    2 (K+V) x layers x page x kv_heads x (codes + 2-byte scales)."""
    pool = PagedKVPool(CFG, 8, 16)
    pages = pool.alloc(3)
    payload = pool.export_pages(pages)
    nbytes = sum(int(v.nbytes) for v in payload.values())
    assert nbytes == 3 * page_handoff_bytes(CFG, 16)


def test_channel_depth_and_counters():
    ch = PageHandoffChannel(depth=1)
    pool = PagedKVPool(CFG, 8, 16)
    pages = pool.alloc(2)
    payload = pool.export_pages(pages)

    class _Req:          # channel only touches the payload
        pass

    ch.push(_Req(), payload)
    assert ch.full and len(ch) == 1
    with pytest.raises(AssertionError):
        ch.push(_Req(), payload)
    assert ch.handoffs == 1 and ch.handoff_pages == 2
    assert ch.handoff_bytes == 2 * page_handoff_bytes(CFG, 16)
    ch.pop()
    assert not ch.full and len(ch) == 0


# ---------------------------------------------------------------------------
# the pinned invariant: 3-way temperature-0 parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_steps", [1, 3])
def test_disagg_matches_interleaved_and_static(k_steps):
    """Ample pools, no preemption/bounce: the disaggregated output is
    token-for-token the interleaved engine's and the static oracle's,
    for single- and multi-step decode dispatches; every handoff crosses
    once at exactly the posit8 page-byte model; the decode worker's
    page table stays epoch-cached across dispatches."""
    reqs = _reqs([(3, 6), (19, 8), (8, 4), (10, 12), (5, 9)])
    kw = dict(prefill_chunk_tokens=16, decode_steps=k_steps)
    disagg, eng_d = _run_disagg(reqs, **kw)
    inter, eng_i = _run_interleaved(reqs, **kw)
    static = ServeEngine(CFG, PARAMS, max_len=48, quantized_kv=True)
    for got_d, got_i, (p, g) in zip(disagg, inter, reqs):
        want = static.generate(jnp.asarray(p)[None], steps=g)[0]
        np.testing.assert_array_equal(got_d, got_i)
        np.testing.assert_array_equal(got_d, want)
    assert eng_d.prefill.scheduler.preemption_count == 0
    assert eng_d.decode_bounces == 0
    assert eng_d.handoffs == len(reqs)
    assert eng_d.handoff_bytes == \
        eng_d.handoff_pages * page_handoff_bytes(CFG, 16)
    # both pools drain on retirement
    assert eng_d.prefill.pool.used_pages == 0
    assert eng_d.decode.pool.used_pages == 0
    # the mapping-epoch protocol survives the handoff: dispatches of an
    # unchanged batch reuse the resident page table
    assert eng_d.page_table_uploads < eng_d.decode_dispatches
    # fused sampling: logits never cross to host on the decode worker
    assert eng_d.logits_host_bytes == 0


def test_disagg_instant_done_retires_prefill_side():
    """A budget-1 request finishes at prefill completion and must never
    cross the channel; it still matches the static oracle."""
    (p, _), = _reqs([(7, 1)])
    out, eng = _run_disagg([(p, 1)])
    static = ServeEngine(CFG, PARAMS, max_len=48, quantized_kv=True)
    np.testing.assert_array_equal(
        out[0], static.generate(jnp.asarray(p)[None], steps=1)[0])
    assert eng.handoffs == 0 and eng.decode_dispatches == 0
    assert list(eng.prefill.scheduler.finished) == [0]


def test_disagg_channel_backpressure_depth1():
    """A depth-1 channel forces completed prefills to park holding
    their pages; outputs are unchanged and every request still crosses
    exactly once."""
    reqs = _reqs([(4, 6), (6, 8), (9, 5), (5, 7)])
    base, _ = _run_disagg(reqs, decode_steps=2)
    tight, eng = _run_disagg(reqs, decode_steps=2, channel_depth=1)
    for a, b in zip(base, tight):
        np.testing.assert_array_equal(a, b)
    assert eng.handoffs == len(reqs)


# ---------------------------------------------------------------------------
# decode-pool pressure: bounce = disaggregated preemption
# ---------------------------------------------------------------------------

def test_disagg_decode_pool_pressure_bounces():
    """A starved DECODE pool bounces requests back across the split
    mid-run: the run stays deterministic, both pools drain, and
    requests that were never bounced still match the ample-pool
    interleaved stream exactly (the same guarantee LIFO preemption
    gives the interleaved engine)."""
    reqs = _reqs([(10, 20), (12, 18), (9, 22), (11, 16)])
    kw = dict(page_size=8, max_batch=4, max_len=40)
    ample, _ = _run_interleaved(reqs, n_pages=32, decode_steps=1, **kw)
    kw_d = dict(prefill_pages=32, decode_pages=7, decode_steps=4, **kw)
    starved, eng = _run_disagg(reqs, **kw_d)
    starved2, _ = _run_disagg(reqs, **kw_d)
    assert eng.decode_bounces > 0
    for a, b in zip(starved, starved2):
        np.testing.assert_array_equal(a, b)
    fin = eng.finished
    for out_a, out_s, rid in zip(ample, starved, sorted(fin)):
        if fin[rid].preemptions == 0:
            np.testing.assert_array_equal(out_a, out_s)
    assert eng.prefill.pool.used_pages == 0
    assert eng.decode.pool.used_pages == 0


def test_disagg_submit_rejects_decode_overflow():
    """The no-livelock guard: a request whose total footprint exceeds
    the decode pool is rejected at submit, not bounced forever."""
    eng = DisaggEngine(CFG, PARAMS, prefill_pages=40, decode_pages=2,
                       page_size=16, max_batch=4, max_len=48)
    with pytest.raises(ValueError, match="decode pool"):
        eng.submit(_reqs([(20, 20)])[0][0], 20)


# ---------------------------------------------------------------------------
# prefix-cache hits cross the split
# ---------------------------------------------------------------------------

def test_disagg_prefix_cache_parity():
    """Shared-preamble requests under the disaggregated engine hit the
    PREFILL-side prefix index and reproduce the interleaved
    prefix-cache stream token for token (both on the pages context);
    the shared pages cross the channel as plain payload copies."""
    pre = RNG.integers(0, CFG.vocab, (16,)).astype(np.int32)
    reqs = [(np.concatenate([pre, t]).astype(np.int32), g)
            for t, g in [(RNG.integers(0, CFG.vocab, (3,)), 6),
                         (RNG.integers(0, CFG.vocab, (5,)), 8),
                         (RNG.integers(0, CFG.vocab, (2,)), 7)]]

    def drive(eng, sched):
        rids = [eng.submit(*reqs[0])]
        for _ in range(3):               # publish the preamble pages
            eng.step()
        rids += [eng.submit(p, g) for p, g in reqs[1:]]
        out = eng.run()
        return [out[r] for r in rids]

    eng_i = ContinuousEngine(CFG, PARAMS, n_pages=40, page_size=16,
                             max_batch=4, max_len=48,
                             prefill_chunk_tokens=16, prefix_cache=True)
    inter = drive(eng_i, eng_i.scheduler)
    eng_d = DisaggEngine(CFG, PARAMS, prefill_pages=40, decode_pages=40,
                         page_size=16, max_batch=4, max_len=48,
                         prefill_chunk_tokens=16, prefix_cache=True)
    disagg = drive(eng_d, eng_d.prefill.scheduler)
    assert eng_d.prefill.scheduler.prefix.hits == \
        eng_i.scheduler.prefix.hits > 0
    for a, b in zip(inter, disagg):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# counter registries: reset_counters derives from _COUNTERS everywhere
# ---------------------------------------------------------------------------

def _assert_registry_zero(obj, label):
    for c in type(obj)._COUNTERS:
        assert getattr(obj, c) == 0, f"{label}.{c} survived reset"
        # the attribute IS a MetricRegistry counter (bind_counters
        # descriptor): the registry-side view must agree
        assert obj._obs_counters[c].value == 0, f"{label}.{c} registry"


def test_interleaved_counter_registry_reset():
    """Regression for the hand-maintained reset: run real traffic, then
    reset and walk EVERY layer's ``_COUNTERS`` registry -- a counter
    added to any registry is reset without touching reset_counters."""
    eng = ContinuousEngine(CFG, PARAMS, n_pages=40, page_size=16,
                           max_batch=4, max_len=48,
                           prefill_chunk_tokens=16, prefix_cache=True)
    eng.submit(*_reqs([(5, 3)])[0])
    eng.run()
    assert eng.steps_run > 0 and eng.prefill_tokens_computed > 0
    # attribute reads and their registry mirrors are the same storage
    assert eng.metrics.value("engine/steps_run") == eng.steps_run
    assert eng.metrics.value("engine/decode_dispatches") == \
        eng.decode_dispatches
    assert eng.metrics.value("scheduler/prefix/hits") == \
        eng.scheduler.prefix.hits
    assert 0.0 <= eng.metrics.value("pool/utilization") <= 1.0
    eng.reset_counters()
    _assert_registry_zero(eng, "engine")
    _assert_registry_zero(eng.scheduler, "scheduler")
    _assert_registry_zero(eng.scheduler.prefix, "prefix")
    assert eng.scheduler.retired_log == []
    assert eng.scheduler.preempted_log == []
    assert eng.pool.alloc_peak == eng.pool.used_pages


def test_disagg_counter_registry_reset():
    eng = DisaggEngine(CFG, PARAMS, prefill_pages=40, decode_pages=40,
                       page_size=16, max_batch=4, max_len=48,
                       prefill_chunk_tokens=16, prefix_cache=True)
    eng.submit(*_reqs([(5, 3)])[0])
    eng.run()
    assert eng.handoffs > 0 and eng.decode_dispatches > 0
    # worker/channel counters mirror into the ONE engine registry under
    # their role namespaces
    assert eng.metrics.value("channel/handoffs") == eng.handoffs
    assert eng.metrics.value("decode/decode_dispatches") == \
        eng.decode_dispatches
    assert eng.metrics.value("prefill/prefill_tokens_computed") == \
        eng.prefill_tokens_computed
    eng.reset_counters()
    _assert_registry_zero(eng, "disagg")
    _assert_registry_zero(eng.prefill, "prefill-worker")
    _assert_registry_zero(eng.decode, "decode-worker")
    _assert_registry_zero(eng.prefill.scheduler, "admitter")
    _assert_registry_zero(eng.prefill.scheduler.prefix, "prefix")
    _assert_registry_zero(eng.decode.runner, "runner")
    _assert_registry_zero(eng.channel, "channel")
    assert eng.decode.runner.retired_log == []
