"""Serving-plane telemetry (PR 8): registry, descriptors, tracing, SLOs.

The pinned contracts:

- legacy ``_COUNTERS`` attributes ARE registry counters (bind_counters
  descriptors): attribute writes and registry reads agree always;
- the trace's per-kind counts / arg-sums are eviction-proof, so
  closed-form tie-outs hold regardless of ring pressure;
- a disabled recorder records NOTHING across a full traffic burst;
- tracing never touches device math: temperature-0 output is bitwise
  identical with the recorder on or off;
- the Chrome-trace export is schema-valid (Perfetto-loadable).
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    NULL_RECORDER,
    TraceRecorder,
    bind_counters,
    pctl_ms,
    percentiles,
    summarize,
    validate_chrome_trace,
)
from repro.serve import ContinuousEngine

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(21)
PARAMS = T.lm_init(jax.random.PRNGKey(0), CFG)


def _reqs(spec):
    return [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in spec]


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = MetricRegistry()
    c = reg.counter("a/b")
    c.inc()
    c.inc(3)
    assert reg.value("a/b") == 4
    c.reset()
    assert c.value == 0

    g = reg.gauge("a/g")
    g.set(2.5)
    assert reg.value("a/g") == 2.5
    g.reset()
    assert g.value == 0

    live = reg.gauge("a/live", fn=lambda: 7)
    assert live.value == 7
    with pytest.raises(ValueError):
        live.set(1)
    reg.reset()                       # fn-gauges survive reset (live)
    assert reg.value("a/live") == 7


def test_registry_kind_mismatch_raises():
    reg = MetricRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")
    assert "x" in reg and reg.names() == ["x"]


def test_histogram_log_buckets():
    h = Histogram("h", lo=1e-3, hi=1e3, per_decade=8)
    vals = [0.5, 1.0, 2.0, 4.0, 8.0, 16.0]
    for v in vals:
        h.observe(v)
    # one log bucket spans 10^(1/8) ~ 1.33x: every percentile is within
    # one bucket width of the exact answer, and clamped to [min, max]
    for q in (50, 95, 99):
        exact = float(np.percentile(vals, q))
        assert h.percentile(q) <= exact * 10 ** (1 / 8) * 1.001
        assert min(vals) <= h.percentile(q) <= max(vals)
    snap = h.snapshot()
    assert snap["count"] == len(vals)
    assert snap["sum"] == pytest.approx(sum(vals))
    assert snap["min"] == 0.5 and snap["max"] == 16.0
    h.observe(1e-9)                   # underflow bucket, not a crash
    assert h.count == len(vals) + 1 and h.vmin == 1e-9
    h.reset()
    assert h.snapshot() == {"count": 0} and h.percentile(50) == 0.0


def test_prometheus_text_snapshot():
    reg = MetricRegistry()
    reg.counter("engine/steps_run").inc(5)
    reg.gauge("pool/utilization", fn=lambda: 0.25)
    reg.histogram("span/step").observe(1.5)
    text = reg.prometheus_text()
    assert "# TYPE repro_engine_steps_run counter" in text
    assert "repro_engine_steps_run 5" in text
    assert "repro_pool_utilization 0.25" in text
    assert "# TYPE repro_span_step summary" in text
    assert "repro_span_step_count 1" in text


def test_bind_counters_descriptor_roundtrip():
    class Legacy:
        _COUNTERS = ("hits", "bytes_moved")

        def __init__(self, reg):
            bind_counters(self, reg, "legacy")

    r1, r2 = MetricRegistry(), MetricRegistry()
    a, b = Legacy(r1), Legacy(r2)
    a.hits += 1
    a.hits += 1
    a.bytes_moved += 128
    b.hits += 5
    # attribute reads, registry reads and instances stay coherent
    assert a.hits == 2 and r1.value("legacy/hits") == 2
    assert a.bytes_moved == 128 and r1.value("legacy/bytes_moved") == 128
    assert b.hits == 5 and r2.value("legacy/hits") == 5
    # the legacy reset idiom writes through the descriptor too
    for c in Legacy._COUNTERS:
        setattr(a, c, 0)
    assert a.hits == 0 and r1.value("legacy/hits") == 0
    assert b.hits == 5                # other instance untouched
    # re-binding is idempotent and zeroes the counters
    bind_counters(b, r2, "legacy")
    assert b.hits == 0


def test_stats_helpers_match_numpy():
    vals = [0.004, 0.001, 0.010, 0.007]
    assert pctl_ms(vals, 50) == pytest.approx(
        float(np.percentile(vals, 50) * 1e3))
    p = percentiles(vals)
    assert set(p) == {"p50", "p95", "p99"}
    assert p["p99"] == pytest.approx(float(np.percentile(vals, 99)))
    s = summarize(vals)
    assert s["n"] == 4 and s["min"] == 0.001 and s["max"] == 0.010
    assert summarize([]) == {"n": 0}


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------

def test_ring_eviction_keeps_counts_exact():
    rec = TraceRecorder(capacity=4)
    for i in range(10):
        rec.event("HANDOFF", rid=i, pages=2, bytes=100)
    assert len(rec) == 4              # ring evicted under pressure...
    assert rec.dropped == 6
    assert rec.count("HANDOFF") == 10          # ...counts never do
    assert rec.arg_sum("HANDOFF", "pages") == 20
    assert rec.arg_sum("HANDOFF", "bytes") == 1000
    rec.clear()
    assert len(rec) == 0 and rec.count("HANDOFF") == 0


def test_disabled_recorder_records_nothing():
    rec = TraceRecorder(enabled=False)
    rec.event("SUBMIT", rid=0, prompt_tokens=4)
    with rec.span("step"):
        pass
    assert len(rec) == 0 and rec.count("SUBMIT") == 0
    assert rec._counts == {} and rec._sums == {}
    # one shared no-op span object: no per-call allocation when off
    assert rec.span("step") is rec.span("decode_sync")


def test_slo_derivation_from_lifecycle_timestamps():
    rec = TraceRecorder()
    t = {"v": 0.0}
    rec._now = lambda: t["v"]         # deterministic clock
    rec.event("SUBMIT", rid=1)
    t["v"] = 0.010
    rec.event("ADMIT", rid=1)
    t["v"] = 0.050
    rec.event("PREFILL_COMPLETE", rid=1)
    t["v"] = 0.150
    rec.event("RETIRE", rid=1, generated=6)
    slo = rec.request_slo()[1]
    assert slo["queue_wait_ms"] == pytest.approx(10.0)
    assert slo["ttft_ms"] == pytest.approx(50.0)
    assert slo["prefill_stall_ms"] == pytest.approx(40.0)
    assert slo["e2e_ms"] == pytest.approx(150.0)
    assert slo["tpot_ms"] == pytest.approx(100.0 / 5)  # 6 tokens -> 5 gaps
    summ = rec.slo_summary()
    assert summ["e2e_ms"]["n"] == 1
    assert summ["e2e_ms"]["p50"] == pytest.approx(150.0)


def test_chrome_trace_schema():
    rec = TraceRecorder()
    rec.event("SUBMIT", rid=0, prompt_tokens=4)
    with rec.span("step"):
        with rec.span("prefill", rid=0, width=4):
            pass
    obj = rec.chrome_trace()
    stats = validate_chrome_trace(obj)
    assert stats["spans"] == 2 and stats["instants"] == 1
    assert stats["total"] == len(obj["traceEvents"])
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "Z", "name": "x", "pid": 0, "tid": 0,
                              "ts": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x", "pid": 0, "tid": 0,
                              "ts": -1.0, "dur": 1.0}]})


def test_exporters_roundtrip(tmp_path):
    rec = TraceRecorder()
    rec.event("SUBMIT", rid=0)
    with rec.span("step"):
        pass
    ct = tmp_path / "trace.json"
    jl = tmp_path / "trace.jsonl"
    rec.write_chrome_trace(str(ct))
    rec.write_jsonl(str(jl))
    with open(ct) as f:
        validate_chrome_trace(json.load(f))
    lines = [json.loads(l) for l in open(jl)]
    assert len(lines) == len(rec)


# ---------------------------------------------------------------------------
# engine integration: parity, tie-outs, disabled path
# ---------------------------------------------------------------------------

def _drive(trace=None):
    eng = ContinuousEngine(CFG, PARAMS, n_pages=40, page_size=16,
                           max_batch=4, max_len=48,
                           prefill_chunk_tokens=16, decode_steps=2,
                           trace=trace)
    reqs = _reqs([(5, 6), (9, 4), (3, 5)])
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    return eng, rids, [out[r] for r in rids]


def test_traffic_burst_leaves_null_recorder_untouched():
    """With tracing off (the default), the shared NULL_RECORDER's ring
    stays empty across a full serve burst -- telemetry-off costs one
    predicted branch, not hidden recording."""
    before = (len(NULL_RECORDER), dict(NULL_RECORDER._counts),
              dict(NULL_RECORDER._sums))
    _drive(trace=None)
    assert len(NULL_RECORDER) == before[0] == 0
    assert NULL_RECORDER._counts == before[1] == {}
    assert NULL_RECORDER._sums == before[2] == {}


def test_traced_engine_parity_and_tieouts(tmp_path):
    global RNG
    RNG = np.random.default_rng(21)   # same request stream both runs
    _, _, plain = _drive(trace=None)
    RNG = np.random.default_rng(21)
    rec = TraceRecorder()
    eng, rids, traced = _drive(trace=rec)
    # tracing never touches device math: bitwise-identical tokens
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a, b)
    # lifecycle counts tie to scheduler/engine counters exactly
    assert rec.count("SUBMIT") == rec.count("RETIRE") == len(rids)
    assert rec.count("DECODE_DISPATCH") == eng.decode_dispatches == \
        eng.metrics.value("engine/decode_dispatches")
    assert rec.count("PREFILL_CHUNK") > 0
    assert rec.arg_sum("PREFILL_CHUNK", "real") == \
        eng.prefill_tokens_computed
    # every request has a full SLO record
    slo = rec.request_slo()
    assert set(slo) == set(rids)
    for s in slo.values():
        assert {"queue_wait_ms", "ttft_ms", "e2e_ms"} <= set(s)
        assert s["e2e_ms"] >= s["ttft_ms"] >= 0.0
    # the export is Perfetto-loadable
    path = tmp_path / "t.json"
    rec.write_chrome_trace(str(path))
    with open(path) as f:
        stats = validate_chrome_trace(json.load(f))
    assert stats["spans"] > 0 and stats["instants"] > 0
