"""Format codec tests: exactness, posit-standard properties, packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # skips property tests w/o hypothesis

from repro.core import formats as F
from repro.core import packing as P
from repro.core import quire as Q

SPECS = [F.FP4, F.POSIT4, F.POSIT8, F.POSIT16, F.FP8_E4M3, F.FP8_E5M2,
         F.FXP4, F.FXP8]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_roundtrip_exact(spec):
    """Every representable value encodes back to itself."""
    vals = F.code_values(spec)
    fin = np.isfinite(vals)
    enc = np.asarray(F.encode(spec, jnp.asarray(vals[fin])))
    dec = np.asarray(F.decode(spec, jnp.asarray(enc)))
    assert np.array_equal(dec, vals[fin])


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_algorithmic_decoder_matches_table(spec):
    """The kernel-safe bit decoder agrees with the exact table decoder
    on every code (NaR/NaN -> 0, the hardware exception path)."""
    vals = F.code_values(spec)
    dec2 = np.asarray(F.decode_bits(spec, jnp.arange(spec.ncodes)))
    tab = np.where(np.isfinite(vals), vals, 0.0)
    assert np.array_equal(dec2, tab)


def test_posit_known_values():
    # posit(8,0): maxpos = 2^6; posit(16,1): maxpos = 2^28; posit(4,1): 16
    assert np.nanmax(F.code_values(F.POSIT8)) == 64.0
    assert np.nanmax(F.code_values(F.POSIT16)) == 2.0 ** 28
    assert np.nanmax(F.code_values(F.POSIT4)) == 16.0
    # fp4 e2m1 value set (OCP)
    v = sorted(set(float(x) for x in F.code_values(F.FP4) if x >= 0))
    assert v == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_encode_monotone_and_saturating():
    for spec in (F.POSIT8, F.FP4, F.POSIT16):
        xs = jnp.linspace(-1e38, 1e38, 4097)
        codes = F.encode(spec, xs)
        vals = np.asarray(F.decode(spec, codes))
        assert np.all(np.diff(vals) >= 0)          # monotone
        assert vals[0] == -np.nanmax(F.code_values(spec))  # clamps
        assert vals[-1] == np.nanmax(F.code_values(spec))


def test_nan_maps_to_nar():
    c = int(F.encode(F.POSIT8, jnp.asarray([float("nan")]))[0])
    assert c == F.nar_code(F.POSIT8) == 0x80


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=-100.0, max_value=100.0,
                 allow_nan=False, allow_infinity=False))
def test_rne_nearest_property_minifloat(x):
    """Minifloat encode picks a nearest representable value (IEEE RNE;
    posits round in BIT space -- covered by the agreement test below)."""
    for spec in (F.FP4, F.FP8_E4M3):
        vals = F.code_values(spec)
        fin = np.sort(vals[np.isfinite(vals)])
        q = float(F.decode(spec, F.encode(spec, jnp.float32(x))))
        best = np.min(np.abs(fin - np.float64(np.float32(x))))
        assert abs(abs(q - np.float32(x)) - best) <= 1e-12


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_algorithmic_encoder_agrees_with_table(seed):
    """The branch-free encoder (hot path) matches the table encoder
    (posit-standard bit-space RNE boundaries) on random sweeps."""
    rng = np.random.default_rng(seed)
    xs = np.concatenate([rng.normal(size=500),
                         rng.normal(size=200) * 1e-5,
                         rng.normal(size=200) * 1e5]).astype(np.float32)
    for spec in (F.POSIT4, F.POSIT8, F.POSIT16, F.FP4, F.FP8_E4M3):
        d_tab = np.asarray(F.decode_bits(spec, F.encode(spec, jnp.asarray(xs))))
        d_alg = np.asarray(F.decode_bits(spec, F.encode_bits(spec,
                                                             jnp.asarray(xs))))
        assert np.array_equal(d_tab, d_alg), spec.name


def test_posit_bitspace_rounding_boundary():
    """Posit-standard (softposit) rounding: the boundary between two
    posits across a regime change is the (n+1)-bit midpoint pattern (the
    geometric mean), NOT the arithmetic midpoint.  posit(4,1): between
    0.0625 (2^-4) and 0.25 (2^-2) the boundary is 2^-3 = 0.125."""
    for x, want in [(0.124, 0.0625), (0.126, 0.25), (0.2, 0.25)]:
        q = float(F.decode(F.POSIT4, F.encode(F.POSIT4, jnp.float32(x))))
        assert q == want, (x, q, want)
    # nonzero never rounds to zero: clamps to +-minpos
    q = float(F.decode(F.POSIT4, F.encode(F.POSIT4, jnp.float32(1e-6))))
    assert q == 0.0625  # minpos of posit(4,1)
    q = float(F.decode(F.POSIT4, F.encode(F.POSIT4, jnp.float32(-1e-6))))
    assert q == -0.0625


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=200),
       st.sampled_from([4, 8, 16]),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_pack_unpack_roundtrip(k, bits, seed):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 1 << bits, size=(3, k))
    w = P.pack(jnp.asarray(c), bits)
    assert w.dtype == jnp.uint32
    back = np.asarray(P.unpack(w, bits, k))
    assert np.array_equal(back, c)


def test_packed_bytes_ratio():
    """The SIMD packing achieves the nominal compression (paper's
    memory-bandwidth claim at the storage level)."""
    shape = (1024, 1024)
    fp32_bytes = 1024 * 1024 * 4
    assert P.packed_nbytes(shape, 4) == fp32_bytes // 8
    assert P.packed_nbytes(shape, 8) == fp32_bytes // 4
    assert P.packed_nbytes(shape, 16) == fp32_bytes // 2


def test_quire_exact_vs_f64():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, 64)
    b = rng.integers(0, 256, 64)
    ex = Q.quire_dot_exact(F.POSIT8, a, b)
    tab = F.code_values(F.POSIT8).astype(np.float64)
    tab = np.where(np.isnan(tab), 0.0, tab)
    assert abs(ex - float(np.sum(tab[a] * tab[b]))) < 1e-9


def test_simd_lanes():
    assert F.simd_lanes(F.FP4) == 4          # 4x per 16-bit lane
    assert F.simd_lanes(F.POSIT8) == 2
    assert F.simd_lanes(F.POSIT16) == 1
