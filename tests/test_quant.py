"""Quantization stack tests: eq. 3-7, STE, sensitivity, policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core import quant, sensitivity
from repro.core.policy import PrecisionPolicy


def test_entropy_scale_eq3():
    w = jnp.asarray([1.0, -1.0, 2.0, -2.0])
    n = 4
    expect = 1.5 * (2 ** n - 1) / 2 ** (n - 1)
    assert np.isclose(float(quant.entropy_scale(w, n)), expect)


def test_pact_eq6_is_clip():
    x = jnp.linspace(-2, 4, 101)
    alpha = jnp.float32(1.5)
    y = quant.pact(x, alpha)
    assert np.allclose(np.asarray(y), np.clip(np.asarray(x), 0, 1.5),
                       atol=1e-6)


def test_pact_quantize_grads():
    """STE: grad flows inside [0, alpha); alpha collects saturated grads."""
    alpha = jnp.float32(1.0)
    x = jnp.asarray([0.3, 0.9, 2.0, -1.0])

    def f(x, a):
        return jnp.sum(quant.pact_quantize(x, a, 4))

    gx, ga = jax.grad(f, argnums=(0, 1))(x, alpha)
    assert np.allclose(np.asarray(gx), [1.0, 1.0, 0.0, 0.0])
    assert float(ga) == 1.0  # one saturated element


def test_fake_quant_ste():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)),
                    jnp.float32)

    def f(w):
        return jnp.sum(jnp.square(quant.fake_quant(F.FP4, w)))

    g = jax.grad(f)(w)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.sum(jnp.abs(g))) > 0  # gradient passes through


def test_fake_quant_error_bounded():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    for spec, tol in [(F.POSIT16, 2e-3), (F.POSIT8, 8e-2), (F.FP4, 0.5)]:
        q = quant.fake_quant(spec, w)
        rel = float(jnp.linalg.norm(q - w) / jnp.linalg.norm(w))
        assert rel < tol, (spec.name, rel)


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((20000,), 0.3)  # between posit8 grid points
    scale = jnp.float32(1.0)
    out = quant.fake_quant_stochastic(F.POSIT8, x, key, scale)
    assert abs(float(jnp.mean(out)) - 0.3) < 5e-3


def test_sensitivity_ranks_low_rank_layers_low():
    """A layer whose weights are exactly representable in low-bit formats
    must score lower than an irregular one (eq. 1-2)."""
    rng = np.random.default_rng(0)
    easy = jnp.asarray(
        np.random.default_rng(1).choice([0.5, 1.0, 2.0], (64, 64)),
        jnp.float32)  # representable in fp4 exactly
    hard = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 3)
    params = {"easy": {"w": easy}, "hard": {"w": hard}}
    grads = jax.tree.map(jnp.ones_like, params)
    s = sensitivity.layer_sensitivity(params, grads)
    assert s["easy/w"] < s["hard/w"]


def test_assign_layer_adaptive_hits_budget():
    rng = np.random.default_rng(0)
    params = {f"l{i}": {"w": jnp.asarray(
        rng.normal(size=(64, 64)).astype(np.float32) * (i + 1))}
        for i in range(6)}
    grads = jax.tree.map(jnp.ones_like, params)
    pol = sensitivity.assign_layer_adaptive(params, grads,
                                            target_avg_bits=6.0)
    bits = pol.average_bits(params)
    assert bits <= 6.05, bits
    # and the policy mixes formats
    names = {pol.format_for(f"l{i}/w").name for i in range(6)}
    assert len(names) >= 2


def test_policy_model_bytes_paper_ratio():
    """FP32 -> mixed HFP4/posit8 model-size reduction is ~5-6x, matching
    the paper's 13.5 MB -> 2.42 MB UL-VIO story."""
    rng = np.random.default_rng(0)
    params = {f"blk{i}": {"w": jnp.asarray(
        rng.normal(size=(256, 256)).astype(np.float32))} for i in range(8)}
    fp32 = PrecisionPolicy.uniform("fp32").model_bytes(params)
    mixed = PrecisionPolicy.paper_mixed().model_bytes(params)
    assert fp32 / mixed > 4.5, (fp32, mixed)


def test_policy_json_roundtrip():
    pol = PrecisionPolicy.paper_mixed()
    pol2 = PrecisionPolicy.from_json(pol.to_json())
    assert pol2.rules == pol.rules and pol2.default == pol.default
