"""Device-resident decode loop (PR 6): K fused decode+sample iterations
per jitted dispatch.  Temperature-0 output must be IDENTICAL for every
``decode_steps`` -- against the static per-request oracle, through
mid-scan EOS, preemption pressure and prefix-cache sharing -- and the
epoch-cached page table must only re-upload when the mapping changed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import ContinuousEngine, PagedKVPool, Scheduler, ServeEngine

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(7)


def _params():
    return T.lm_init(jax.random.PRNGKey(0), CFG)


PARAMS = _params()


def _reqs(spec):
    return [(RNG.integers(0, CFG.vocab, (ln,)).astype(np.int32), gn)
            for ln, gn in spec]


def _run(reqs, k_steps, **kw):
    kw.setdefault("n_pages", 40)
    kw.setdefault("page_size", 16)
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_len", 48)
    eng = ContinuousEngine(CFG, PARAMS, decode_steps=k_steps, **kw)
    rids = [eng.submit(p, g) for p, g in reqs]
    out = eng.run()
    if not kw.get("prefix_cache"):
        # drained (prefix caching intentionally retains cached pages)
        assert eng.pool.used_pages == 0
    return [out[r] for r in rids], eng


# ---------------------------------------------------------------------------
# temperature-0 parity: the pinned invariant, for every K
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_steps", [1, 4])
def test_decode_loop_matches_static_per_request(k_steps):
    """Ragged overlapping requests decoded K at a time match the static
    per-request oracle token for token: a dead row's frozen iterations
    (parking-page writes, position 0) must not perturb live rows."""
    reqs = _reqs([(3, 6), (5, 12), (8, 4), (10, 20), (4, 9), (7, 15)])
    out, _ = _run(reqs, k_steps)
    static = ServeEngine(CFG, PARAMS, max_len=48, quantized_kv=True)
    for got, (p, g) in zip(out, reqs):
        want = static.generate(jnp.asarray(p)[None], steps=g)[0]
        np.testing.assert_array_equal(got, want)


def test_decode_loop_eos_mid_scan():
    """An EOS landing in the MIDDLE of the K-step scan (not on a
    dispatch boundary) retires the request at exactly the K=1 length;
    the frozen tail iterations write only to the parking page."""
    p = RNG.integers(0, CFG.vocab, (5,)).astype(np.int32)
    (gen,), _ = _run([(p, 12)], 1, n_pages=12, max_batch=2)
    gen = gen[p.size:]
    # an unrepeated token at a stream index that is NOT -1 mod 4, so at
    # K=4 the row really freezes mid-scan
    k = max(i for i, v in enumerate(gen)
            if v not in gen[:i] and i < gen.size - 1 and (i + 1) % 4)
    eos = int(gen[k])
    for k_steps in (1, 4):
        (out,), eng = _run([(p, 12)], k_steps, n_pages=12, max_batch=2,
                           eos_id=eos)
        assert out.size == p.size + k + 1 and out[-1] == eos, k_steps
        assert eng.pool.used_pages == 0


def test_decode_loop_preemption_pressure():
    """A starved pool preempts mid-run at K=4: the run stays
    deterministic, every page returns, and requests that were never
    preempted still match the ample-pool K=1 stream exactly."""
    reqs = _reqs([(10, 20), (12, 18), (9, 22), (11, 16)])
    kw = dict(page_size=8, max_batch=4, max_len=40)
    ample, _ = _run(reqs, 1, n_pages=32, **kw)
    starved, eng = _run(reqs, 4, n_pages=7, **kw)
    starved2, _ = _run(reqs, 4, n_pages=7, **kw)
    assert eng.scheduler.preemption_count > 0
    pre = [eng.scheduler.finished[r].preemptions
           for r in sorted(eng.scheduler.finished)]
    for a, b in zip(starved, starved2):
        np.testing.assert_array_equal(a, b)
    for out_a, out_s, n_pre in zip(ample, starved, pre):
        if n_pre == 0:
            np.testing.assert_array_equal(out_a, out_s)


def test_decode_loop_prefix_cache_parity():
    """Shared-preamble requests decoded K=4 reproduce the K=1 stream:
    copy-on-write page sharing and the epoch cache compose.  The first
    sharer prefills ALONE so its preamble pages are published before
    the later arrivals are admitted (else nobody hits)."""
    pre = RNG.integers(0, CFG.vocab, (16,)).astype(np.int32)
    reqs = [(np.concatenate([pre, t]).astype(np.int32), g)
            for t, g in [(RNG.integers(0, CFG.vocab, (3,)), 6),
                         (RNG.integers(0, CFG.vocab, (5,)), 8),
                         (RNG.integers(0, CFG.vocab, (2,)), 7)]]

    def run(k_steps):
        eng = ContinuousEngine(CFG, PARAMS, decode_steps=k_steps,
                               n_pages=40, page_size=16, max_batch=4,
                               max_len=48, prefill_chunk_tokens=16,
                               prefix_cache=True)
        rids = [eng.submit(*reqs[0])]
        for _ in range(3):               # publish the preamble pages
            eng.step()
        rids += [eng.submit(p, g) for p, g in reqs[1:]]
        out = eng.run()
        return [out[r] for r in rids], eng

    base, eng1 = run(1)
    k4, eng4 = run(4)
    assert eng4.scheduler.prefix.hits == eng1.scheduler.prefix.hits > 0
    for a, b in zip(base, k4):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# seeded sampling: per-(request, token-index) streams are K-invariant
# ---------------------------------------------------------------------------

def test_decode_loop_seeded_sampling_k_invariant():
    """temperature > 0: the fused sampler folds (rid, token index) into
    the engine seed, so the SAME seed yields the SAME stream for every
    K, and a different seed yields a different stream."""
    reqs = _reqs([(4, 10), (6, 8)])
    kw = dict(max_batch=4, temperature=0.8)
    a, _ = _run(reqs, 1, seed=3, **kw)
    b, _ = _run(reqs, 4, seed=3, **kw)
    c, _ = _run(reqs, 4, seed=4, **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_static_fused_sampling_deterministic():
    """ServeEngine.generate samples on device: same key -> identical
    output, different key -> different tokens, temperature 0 ignores
    the key entirely."""
    eng = ServeEngine(CFG, PARAMS, max_len=32, quantized_kv=True)
    toks = jnp.asarray(RNG.integers(0, CFG.vocab, (2, 5)), jnp.int32)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    a = eng.generate(toks, steps=8, temperature=0.7, key=k1)
    b = eng.generate(toks, steps=8, temperature=0.7, key=k1)
    c = eng.generate(toks, steps=8, temperature=0.7, key=k2)
    g1 = eng.generate(toks, steps=8, temperature=0.0, key=k1)
    g2 = eng.generate(toks, steps=8, temperature=0.0, key=k2)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    np.testing.assert_array_equal(g1, g2)


# ---------------------------------------------------------------------------
# page-table epoch cache
# ---------------------------------------------------------------------------

def _sched(n_pages=8, page_size=4, max_batch=4):
    return Scheduler(PagedKVPool(CFG, n_pages, page_size), max_batch)


def test_epoch_bumps_on_every_mapping_change():
    """admit / prefill_complete / page growth / preempt / retire each
    advance the scheduler epoch (a missed bump would leave a stale page
    table resident on device: silent KV corruption)."""
    s = _sched(n_pages=6, page_size=4, max_batch=4)
    e = s.epoch
    s.submit(np.arange(1, 7, dtype=np.int32), 8)
    s.submit(np.arange(1, 7, dtype=np.int32), 8)
    a, b = s.admit()
    assert s.epoch > e
    e = s.epoch
    assert s.ensure_prefill_capacity(a, 6)
    a.prefilled = 6
    s.prefill_complete(a)
    assert s.epoch > e                   # completion changes the row
    e = s.epoch
    a.generated = [9, 9, 9]              # position 9 -> needs a 3rd page
    assert s.ensure_capacity(a)
    assert s.epoch > e                   # growth remaps
    e = s.epoch
    assert s.ensure_capacity(a) is True  # no growth needed...
    assert s.epoch == e                  # ...no spurious bump
    s.preempt(a)
    assert s.epoch > e
    e = s.epoch
    assert s.ensure_prefill_capacity(b, 6)
    b.prefilled = 6
    s.prefill_complete(b)
    e = s.epoch
    s.retire(b)
    assert s.epoch > e


def test_horizon_preclaims_whole_scan_window():
    """ensure_capacity(horizon=K) must cover position..position+K-1: a
    page missing mid-scan would be an unaddressable device write."""
    s = _sched(n_pages=8, page_size=4, max_batch=2)
    s.submit(np.arange(1, 4, dtype=np.int32), 16)
    (r,) = s.admit()
    assert s.ensure_prefill_capacity(r, 3)
    r.prefilled = 3
    s.prefill_complete(r)
    assert len(r.pages) == 1             # position 3: one page
    assert s.ensure_capacity(r, horizon=8)
    assert len(r.pages) == 3             # writes reach position 10


def test_page_table_upload_cached_across_dispatches():
    """Steady-state decode re-uses the resident page table: uploads
    happen only on admission and page-boundary growth, so with K=1 the
    upload count stays far below the dispatch count."""
    eng = ContinuousEngine(CFG, PARAMS, n_pages=12, page_size=16,
                           max_batch=2, max_len=48)
    rid = eng.submit(RNG.integers(0, CFG.vocab, (4,)).astype(np.int32), 20)
    eng.run()
    assert len(eng.scheduler.finished[rid].generated) == 20
    assert eng.decode_dispatches == 19   # token 1 is prefill-sampled
    # one upload at admission, one when decode crosses into page 2
    assert eng.page_table_uploads == 2, eng.page_table_uploads
    assert eng.logits_host_bytes == 0
    assert eng.token_host_bytes == 19 * 2 * 1 * 4   # (B=2, K=1) int32
