"""End-to-end behaviour tests for the paper's system: the full
XR-perception pipeline (sensitivity -> layer-adaptive policy -> QAT ->
packed serving) on the paper's own workloads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import formats as F
from repro.core.policy import PrecisionPolicy
from repro.core.sensitivity import assign_layer_adaptive
from repro.data.vio_data import VIOStream
from repro.models import perception as P
from repro.models import zoo


def test_vio_trains_and_quantizes():
    """UL-VIO analogue: train fp32, derive a layer-adaptive policy from
    eq.1-2, check the quantized model's RMSE degradation stays small
    (paper: FP4 costs ~0.7pp translation RMSE; mixed is better)."""
    stream = VIOStream(batch=64)
    params = P.vio_init(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, batch, lr):
        (l, metrics), g = jax.value_and_grad(P.vio_loss, has_aux=True)(
            p, batch)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l, metrics

    for i in range(300):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, loss, metrics = step(params, b, 1e-3)
    t0 = float(metrics["t_rmse"])
    assert t0 < 0.5, t0  # learned something real

    # calibration gradient -> eq.1-2 policy
    b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    grads = jax.grad(lambda p: P.vio_loss(p, b)[0])(params)
    policy = assign_layer_adaptive(params, grads, target_avg_bits=6.0)

    from repro.core.qat import quantize_tree
    qparams = quantize_tree(params, policy)
    _, m_q = P.vio_loss(qparams, b)
    _, m_f = P.vio_loss(params, b)
    # mixed-precision degradation stays small in absolute terms
    assert float(m_q["t_rmse"]) - float(m_f["t_rmse"]) < 0.1


def test_model_size_reduction_paper_claim():
    """Paper: 13.5 MB (FP32) -> 2.42 MB mixed (~5.6x).  Our policy
    machinery must reproduce that ratio on a VIO-sized model."""
    params = P.vio_init(jax.random.PRNGKey(0))
    fp32 = PrecisionPolicy.uniform("fp32").model_bytes(params)
    mixed = PrecisionPolicy.paper_mixed().model_bytes(params)
    assert 4.0 < fp32 / mixed < 9.0, (fp32, mixed)


def test_classifier_precision_sweep_monotone():
    """Fig.5 analogue: accuracy at posit16 >= posit8 >= posit4 (fp4 ~
    posit4 band), after short training."""
    rng = np.random.default_rng(0)
    templates = rng.normal(size=(10, 16, 16, 3)).astype(np.float32)

    def make_batch(n=64):
        y = rng.integers(0, 10, n)
        x = templates[y] + rng.normal(size=(n, 16, 16, 3)).astype(
            np.float32) * 0.5
        return {"images": jnp.asarray(x), "labels": jnp.asarray(y)}

    from repro.optim import OptConfig, adamw_init, adamw_update
    params = P.classifier_init(jax.random.PRNGKey(1), width=16)
    ocfg = OptConfig(weight_decay=0.0)
    ost = adamw_init(params, ocfg)

    @jax.jit
    def step(p, ost, batch):
        (l, m), g = jax.value_and_grad(P.classifier_loss, has_aux=True)(
            p, batch)
        p, ost = adamw_update(p, g, ost, 3e-3, ocfg)
        return p, ost, m

    for _ in range(150):
        params, ost, m = step(params, ost, make_batch())
    test_b = make_batch(256)
    accs = {}
    from repro.core.qat import quantize_tree
    for name in ("posit16_1", "posit8_0", "fp4"):
        q = quantize_tree(params, PrecisionPolicy.uniform(name))
        _, m = P.classifier_loss(q, test_b)
        accs[name] = float(m["acc"])
    _, m = P.classifier_loss(params, test_b)
    acc_fp32 = float(m["acc"])
    assert acc_fp32 > 0.8
    assert accs["posit16_1"] > acc_fp32 - 0.05
    assert accs["posit8_0"] > acc_fp32 - 0.10
    # fp4 degrades but stays usable (paper's "near-BF16" claim is after
    # QAT; post-training here, so the bar is lower)
    assert accs["fp4"] > 0.4


def test_serving_plane_bytes_vs_dense():
    cfg = get_config("qwen2-0.5b").reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    from repro.core.policy import flatten_with_paths
    dense_bytes = sum(np.prod(l.shape) * 4
                      for _, l in flatten_with_paths(params))
    packed = zoo.pack_params(params, PrecisionPolicy.uniform("fp4"))
    from repro.kernels.ops import PackedTensor
    packed_bytes = 0
    def walk(n):
        global packed_bytes
        if isinstance(n, dict):
            for v in n.values():
                walk(v)
        elif isinstance(n, PackedTensor):
            pass
    # count via flatten (PackedTensor flattens to words/scales/mask)
    pb = sum(np.prod(l.shape) * l.dtype.itemsize
             for _, l in flatten_with_paths(packed))
    assert pb < dense_bytes * 0.45  # embed stays fp32; matrices 8x smaller
