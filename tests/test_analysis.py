"""Tests for the static-analysis framework (``tools/analysis``).

Per rule: a fixture that FIRES on the bad pattern, a twin that stays
QUIET on the good one, and a ``# repro: allow(<rule>)`` suppression
check.  Plus the meta-invariants: the registry carries >= 5 active
rules, the full-repo run is clean (the pass ships with zero
grandfathered findings), and the runtime half -- the compile-count
sentinel and the transfer guard -- behaves on a live engine."""

import os
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from tools.analysis import (FileContext, RepoContext, all_rules,  # noqa: E402
                            run_paths, run_source)
from tools.analysis.rules import kernel_oracle, obs_counters  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serve.engine import (ContinuousEngine, _device_only,  # noqa: E402
                                _trace_counted)

SERVE_PATH = "src/repro/serve/engine.py"


def _src(code: str) -> str:
    return textwrap.dedent(code).lstrip("\n")


def _rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# framework
# ---------------------------------------------------------------------------

def test_registry_has_at_least_five_rules():
    rules = all_rules()
    assert len(rules) >= 5
    names = {r.name for r in rules}
    assert {"host-sync", "donation-safety", "jit-in-step",
            "kernel-oracle", "determinism",
            "obs-counter-discipline"} <= names
    for r in rules:
        assert r.check_file or r.check_repo


def test_full_repo_run_is_clean():
    assert run_paths() == []


def test_allow_comment_on_same_line_and_line_above():
    bad = _src("""
        import time
        def f():
            t = time.time()
    """)
    assert _rules_of(run_source(bad, path="src/repro/x.py")) \
        == {"determinism"}
    same_line = bad.replace("time.time()",
                            "time.time()  # repro: allow(determinism)")
    assert run_source(same_line, path="src/repro/x.py") == []
    above = bad.replace("    t = time.time()",
                        "    # repro: allow(determinism)\n"
                        "    t = time.time()")
    assert run_source(above, path="src/repro/x.py") == []
    wildcard = bad.replace("time.time()",
                           "time.time()  # repro: allow(*)")
    assert run_source(wildcard, path="src/repro/x.py") == []
    wrong_rule = bad.replace("time.time()",
                             "time.time()  # repro: allow(host-sync)")
    assert _rules_of(run_source(wrong_rule, path="src/repro/x.py")) \
        == {"determinism"}


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_fires_on_step_path_syncs():
    bad = _src("""
        import numpy as np
        import jax.numpy as jnp
        class Engine:
            def step(self):
                toks = np.asarray(self._disp)
                n = self._count.item()
                lg = jnp.argmax(self._logits)
                k = int(lg)
                print(toks)
                return k + n
    """)
    findings = run_source(bad, path=SERVE_PATH, rules=["host-sync"])
    assert len(findings) == 4            # np.asarray, .item, int(), print
    assert _rules_of(findings) == {"host-sync"}


def test_host_sync_quiet_on_sanctioned_device_get_and_cold_paths():
    good = _src("""
        import numpy as np
        import jax
        class Engine:
            def step(self):
                toks = jax.device_get(self._disp)
                return int(toks[0, 0])
            def generate(self, out):
                return np.asarray(out)     # not a step-path function
    """)
    assert run_source(good, path=SERVE_PATH, rules=["host-sync"]) == []


def test_host_sync_step_check_scoped_to_serve():
    bad = _src("""
        import numpy as np
        class Engine:
            def step(self):
                return np.asarray(self._disp)
    """)
    assert run_source(bad, path="src/repro/train/loop.py",
                      rules=["host-sync"]) == []


def test_host_sync_fires_on_cast_in_loop_anywhere():
    bad = _src("""
        import jax.numpy as jnp
        def bench(mats):
            acc = 0.0
            for m in mats:
                acc += float(jnp.sum(m))
            return acc
    """)
    findings = run_source(bad, path="benchmarks/bench_x.py",
                          rules=["host-sync"])
    assert len(findings) == 1
    good = bad.replace("acc += float(jnp.sum(m))",
                       "acc = acc + jnp.sum(m)")
    assert run_source(good, path="benchmarks/bench_x.py",
                      rules=["host-sync"]) == []


# ---------------------------------------------------------------------------
# donation-safety
# ---------------------------------------------------------------------------

def test_donation_fires_on_read_after_donating_call():
    bad = _src("""
        import jax
        class Engine:
            def __init__(self, fn):
                self._loop = jax.jit(fn, donate_argnums=(3,))
            def run(self, params, toks, pos, state):
                out = self._loop(params, toks, pos, state)
                return out, state.shape      # state's buffer is gone
    """)
    findings = run_source(bad, path=SERVE_PATH,
                          rules=["donation-safety"])
    assert len(findings) == 1
    assert "state" in findings[0].message


def test_donation_quiet_on_rebind_and_other_keys():
    good = _src("""
        import functools
        import jax
        @functools.partial(jax.jit, donate_argnums=(0,))
        def write(buf, chunk):
            return buf.at[0].set(chunk)
        class Engine:
            def __init__(self, fn):
                self._loop = jax.jit(fn, donate_argnums=(3,))
            def run(self, params, toks, pos, state):
                state = self._loop(params, toks, pos, state)
                return state                 # rebound: the NEW buffer
            def chunk(self, ctx, kv):
                ctx = {"k": write(ctx["k"], kv["k"]),
                       "v": write(ctx["v"], kv["v"])}
                return ctx
    """)
    assert run_source(good, path=SERVE_PATH,
                      rules=["donation-safety"]) == []


def test_donation_fires_on_subscript_key_reuse():
    bad = _src("""
        import functools
        import jax
        @functools.partial(jax.jit, donate_argnums=(0,))
        def write(buf, chunk):
            return buf.at[0].set(chunk)
        def f(ctx, kv):
            new_k = write(ctx["k"], kv)
            stale = ctx["k"]                 # donated buffer
            return new_k, stale
    """)
    findings = run_source(bad, path=SERVE_PATH,
                          rules=["donation-safety"])
    assert len(findings) == 1
    assert "ctx['k']" in findings[0].message


def test_donation_respects_allow():
    bad = _src("""
        import jax
        class Engine:
            def __init__(self, fn):
                self._loop = jax.jit(fn, donate_argnums=(0,))
            def run(self, state):
                out = self._loop(state)
                return out, state  # repro: allow(donation-safety)
    """)
    assert run_source(bad, path=SERVE_PATH,
                      rules=["donation-safety"]) == []


# ---------------------------------------------------------------------------
# jit-in-step
# ---------------------------------------------------------------------------

def test_jit_in_step_fires_in_loop_and_step_body():
    bad = _src("""
        import jax
        import jax.experimental.pallas as pl
        def run(fns, xs):
            for fn in fns:
                step = jax.jit(fn)        # fresh trace cache per iter
                xs = step(xs)
            return xs
        class Engine:
            def step(self, x):
                return pl.pallas_call(self._kernel)(x)
    """)
    findings = run_source(bad, path=SERVE_PATH, rules=["jit-in-step"])
    assert len(findings) == 2


def test_jit_in_step_quiet_on_init_construction():
    good = _src("""
        import jax
        class Engine:
            def __post_init__(self):
                self._step = jax.jit(self._fn)
            def step(self, x):
                return self._step(x)
    """)
    assert run_source(good, path=SERVE_PATH, rules=["jit-in-step"]) == []
    # loop-construction outside src/repro (e.g. a bench sweeping
    # configs) is out of scope
    loop = _src("""
        import jax
        def sweep(fns, x):
            for fn in fns:
                x = jax.jit(fn)(x)
            return x
    """)
    assert run_source(loop, path="benchmarks/bench_x.py",
                      rules=["jit-in-step"]) == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_determinism_fires_in_scheduler_decision_paths():
    bad = _src("""
        import random
        import time
        class Scheduler:
            def admit(self, queue):
                random.shuffle(queue)
                self._stamp = time.time()
                return queue
    """)
    findings = run_source(bad, path="src/repro/serve/scheduler.py",
                          rules=["determinism"])
    # random.shuffle + time.time (decision path) + time.time (the
    # everywhere wall-clock check)
    assert len(findings) == 3


def test_determinism_set_iteration_in_serve():
    bad = _src("""
        def batch(rids):
            return [r for r in set(rids)]
    """)
    findings = run_source(bad, path="src/repro/serve/scheduler.py",
                          rules=["determinism"])
    assert len(findings) == 1
    good = bad.replace("set(rids)", "sorted(set(rids))")
    assert run_source(good, path="src/repro/serve/scheduler.py",
                      rules=["determinism"]) == []


def test_determinism_perf_counter_is_legal_everywhere():
    good = _src("""
        import time
        class Scheduler:
            def admit(self, queue):
                self._t0 = time.perf_counter()   # telemetry stamp
                return queue
    """)
    assert run_source(good, path="src/repro/serve/scheduler.py",
                      rules=["determinism"]) == []


# ---------------------------------------------------------------------------
# kernel-oracle (repo-level: exercised through an injected table)
# ---------------------------------------------------------------------------

def test_kernel_oracle_clean_on_real_table():
    assert kernel_oracle.check_table(RepoContext(),
                                     kernel_oracle.KERNEL_TABLE) == []


def test_kernel_oracle_fires_on_missing_entry_oracle_and_stale():
    repo = RepoContext()
    # drop one kernel's entry -> "no KERNEL_TABLE entry"
    table = dict(kernel_oracle.KERNEL_TABLE)
    del table["flash_decode_pallas"]
    msgs = [f.message for f in kernel_oracle.check_table(repo, table)]
    assert any("flash_decode_pallas" in m and "no KERNEL_TABLE entry" in m
               for m in msgs)
    # point one entry at a nonexistent oracle and fallback
    table = dict(kernel_oracle.KERNEL_TABLE)
    table["flash_decode_pallas"] = (
        "no_such_ref", "src/repro/models/attention.py", "no_such_fn")
    msgs = [f.message for f in kernel_oracle.check_table(repo, table)]
    assert any("no_such_ref" in m for m in msgs)
    assert any("no_such_fn" in m for m in msgs)
    # stale entry for a kernel that does not exist
    table = dict(kernel_oracle.KERNEL_TABLE)
    table["ghost_pallas"] = ("flash_decode_ref",
                             "src/repro/models/attention.py",
                             "decode_quantized_blocks")
    msgs = [f.message for f in kernel_oracle.check_table(repo, table)]
    assert any("stale" in m and "ghost_pallas" in m for m in msgs)


def test_kernel_oracle_discovers_every_public_kernel():
    kernels = kernel_oracle.discover_kernels(RepoContext())
    assert set(kernels) == set(kernel_oracle.KERNEL_TABLE)
    assert len(kernels) >= 6


# ---------------------------------------------------------------------------
# obs-counter-discipline (parity with the old standalone checker)
# ---------------------------------------------------------------------------

def _obs_findings(code: str):
    ctx = FileContext("src/repro/serve/fixture.py", _src(code))
    return obs_counters.check_sources({ctx.path: ctx})


def test_obs_counters_fires_on_bare_counter_and_missing_bind():
    findings = _obs_findings("""
        class Engine:
            _COUNTERS = ("steps_run",)
            def __init__(self):
                self.steps_run = 0
            def step(self):
                self.steps_run += 1
                self.stray += 1
    """)
    msgs = [f.message for f in findings]
    assert any("never calls bind_counters" in m for m in msgs)
    assert any("stray" in m for m in msgs)
    assert len(findings) == 2


def test_obs_counters_quiet_on_bound_registry_counters():
    assert _obs_findings("""
        class Engine:
            _COUNTERS = ("steps_run",)
            def __init__(self, registry):
                bind_counters(self, registry, "engine")
            def step(self):
                self.steps_run += 1
                self._private += 1
                self.epoch += 1          # allowlisted versioning token
    """) == []


def test_obs_counters_live_repo_is_clean():
    assert run_paths(paths=[], rules=["obs-counter-discipline"]) == []


# ---------------------------------------------------------------------------
# runtime guards (the dynamic half of the pass)
# ---------------------------------------------------------------------------

def test_trace_counted_counts_traces_not_calls():
    counts = {}
    fn = jax.jit(_trace_counted(lambda x: x * 2, counts, "f"))
    assert counts["f"] == 0
    x = jnp.arange(4)
    fn(x)
    fn(x)
    fn(x)
    assert counts["f"] == 1              # one trace, three calls
    fn(jnp.arange(8))                    # new shape bucket -> retrace
    assert counts["f"] == 2


def test_device_only_guard_blocks_implicit_transfers():
    f = jax.jit(lambda x: x + 1)
    f(jnp.arange(4))                     # compile outside the guard
    with _device_only(True):
        f(jnp.asarray(np.arange(4)))     # explicit staging: legal
        jax.device_get(jnp.arange(4))    # sanctioned sync: legal
        with pytest.raises(Exception):
            f(np.arange(4))              # implicit h2d upload
    with _device_only(False):
        f(np.arange(4))                  # guard off: a no-op context


def test_continuous_engine_sentinel_flat_under_guard():
    cfg = get_config("qwen2-0.5b").reduced()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(cfg, params, n_pages=16, page_size=16,
                           max_batch=2, max_len=32, decode_steps=2)
    # the paged-context chunk step is built only under
    # prefill_context="pages" (and never for stateful families), so the
    # default carry engine registers two sentinels
    assert set(eng.trace_counts) == {"prefill_chunk", "decode_loop"}
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
    rid = eng.submit(prompt, 5)
    eng.run()
    assert eng.trace_counts["decode_loop"] >= 1
    warm = dict(eng.trace_counts)
    # steady state under the transfer guard: same shapes, zero
    # retraces, identical temp-0 output
    eng.transfer_guard = True
    rid2 = eng.submit(prompt, 5)
    eng.run()
    assert eng.trace_counts == warm
    fin = eng.scheduler.finished
    assert list(fin[rid].generated) == list(fin[rid2].generated)
