"""Copy-on-write prefix caching over the paged KV pool: PrefixIndex
bookkeeping (capped matching, refcount pinning, LRU leaf-first
eviction), hit-aware admission budgeting, eviction-before-preemption,
and ContinuousEngine cache-hit parity / logical-KV oracle checks."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import ContinuousEngine, PagedKVPool, Scheduler
from repro.serve.scheduler import PrefixIndex

CFG = get_config("qwen2-0.5b").reduced()
RNG = np.random.default_rng(0)


def _params():
    return T.lm_init(jax.random.PRNGKey(0), CFG)


def _sched(n_pages=8, page_size=4, max_batch=4, **kw):
    return Scheduler(PagedKVPool(CFG, n_pages, page_size), max_batch, **kw)


def _prompt(n):
    return np.arange(1, n + 1, dtype=np.int32)


def _page_in(s, req):
    """Drive a PREFILLING request's page side to completion (what the
    engine's chunk loop does, minus the model)."""
    assert s.ensure_prefill_capacity(req, len(req.prefix))
    req.prefilled = len(req.prefix)
    s.prefill_complete(req)


def _shared(n_tail, pre):
    """A prompt opening with the shared preamble ``pre``."""
    return np.concatenate(
        [pre, RNG.integers(0, CFG.vocab, (n_tail,)).astype(np.int32)])


# ---------------------------------------------------------------------------
# PrefixIndex unit tests (no model involved)
# ---------------------------------------------------------------------------

def test_index_match_is_capped_and_chained():
    pool = PagedKVPool(CFG, n_pages=8, page_size=4)
    idx = PrefixIndex(pool)
    prompt = _prompt(12)                       # 3 whole pages
    pages = pool.alloc(3)
    idx.insert(prompt, pages)
    assert len(idx) == 3
    # page-aligned prompt: the LAST page never matches -- its tokens
    # are recomputed so the hit still produces first-sample logits (and
    # the page its decode may write stays private)
    keys = idx.match(prompt)
    assert [idx._entries[k].page for k in keys] == pages[:2]
    # one more token and all 3 cached blocks are strictly before the
    # last-token page: full 3-block match
    assert len(idx.match(_prompt(13))) == 3
    # a diverging second block stops the chain after one page
    other = _prompt(13)
    other[5] = 9999
    assert len(idx.match(other)) == 1
    # no whole page in common with a 3-token prompt
    assert idx.match(_prompt(3)) == []
    # re-inserting is a no-op (no duplicate entries, no double incref)
    idx.insert(prompt, pages)
    assert len(idx) == 3
    assert [pool.refcount(p) for p in pages] == [2, 2, 2]


def test_index_acquire_pins_and_eviction_is_leaf_first():
    pool = PagedKVPool(CFG, n_pages=8, page_size=4)
    idx = PrefixIndex(pool)
    pages = pool.alloc(3)
    idx.insert(_prompt(12), pages)
    pool.free(pages)                           # the prefiller retires
    assert pool.used_pages == 3                # ...but the cache holds on
    assert [pool.refcount(p) for p in pages] == [1, 1, 1]
    assert idx.reclaimable_pages() == 3
    shared = idx.acquire(_prompt(12))          # capped hit: 2 of 3 blocks
    assert shared == pages[:2]
    assert [pool.refcount(p) for p in pages] == [2, 2, 1]
    # pinned pages are not reclaimable, and neither is an unpinned
    # parent below a pinned child -- only the true leaf is
    assert idx.reclaimable_pages() == 1
    assert idx.evict(3) == 1                   # pinned chain survives
    assert pool.used_pages == 2 and len(idx) == 2
    pool.free(shared)                          # the sharer lets go
    assert idx.reclaimable_pages() == 2
    assert idx.evict(5) == 2                   # leaf first, then its parent
    assert pool.used_pages == 0 and len(idx) == 0


# ---------------------------------------------------------------------------
# scheduler: hit-aware admission, eviction before preemption, submit guard
# ---------------------------------------------------------------------------

def test_admission_attaches_hit_and_budgets_only_new_pages():
    s = _sched(n_pages=3, page_size=4, prefix_cache=True)
    r0 = s.submit(_prompt(9), 3)               # 3 pages, 2 whole-prompt
    (a,) = s.admit()
    _page_in(s, a)
    cached = list(a.pages[:2])
    a.generated = [7]
    s.retire(a)
    assert s.pool.used_pages == 2              # prompt pages stay cached
    assert s.pool.free_pages == 1
    # the same prompt again: needs pages_for(10) = 3, but 2 arrive
    # shared, so the single free page covers the whole remaining need
    s.submit(_prompt(9), 3)
    (b,) = s.admit()
    assert b.pages == cached                   # attached in block order
    assert b.prefilled == 8 and b.cached_tokens == 8
    assert s.prefix.hits == 1 and s.prefix.hit_tokens == 8
    assert [s.pool.refcount(p) for p in b.pages] == [2, 2]
    assert s.ensure_prefill_capacity(b, 9)     # 3rd page: the free one
    assert s.preemption_count == 0


def test_grow_evicts_cache_before_preempting():
    s = _sched(n_pages=3, page_size=4, max_batch=2, prefix_cache=True)
    s.submit(_prompt(9), 3)
    (a,) = s.admit()
    _page_in(s, a)
    a.generated = [7]
    s.retire(a)
    assert s.pool.free_pages == 1              # 2 cached, 1 free
    # an UNRELATED request needing the whole pool: admission counts the
    # reclaimable cached pages, and prefill growth EVICTS them (LRU)
    # instead of preempting anybody
    s.submit(np.full(9, 50, np.int32), 3)
    (b,) = s.admit()
    assert b.pages == [] and b.cached_tokens == 0      # a miss
    assert s.ensure_prefill_capacity(b, 9)
    assert len(b.pages) == 3
    assert s.prefix.evictions == 2
    assert s.preemption_count == 0 and len(s.waiting) == 0


def test_submit_rejects_page_table_overflow():
    """A direct scheduler user gets the engine's rejection at submit:
    a page list wider than the engine's fixed (B, NP) page-table row
    can never be decoded, however big the pool is."""
    s = _sched(n_pages=8, page_size=4, max_pages_per_req=2)
    s.submit(_prompt(5), 3)                    # 2 pages: fits the row
    with pytest.raises(ValueError, match="exceeds max_len"):
        s.submit(_prompt(5), 4)                # 3 pages > 2-page row


# ---------------------------------------------------------------------------
# ContinuousEngine end-to-end
# ---------------------------------------------------------------------------

def test_engine_prefix_hit_parity_and_hit_accounting():
    """Cache-hit requests produce temperature-0 outputs token-for-token
    identical to the cache-off engine (both on the pages context: the
    shared pages hold bitwise the codes a cold prefill writes), and the
    hit counters record exactly the skipped preamble."""
    params = _params()
    pre = RNG.integers(0, CFG.vocab, (32,)).astype(np.int32)
    reqs = [(_shared(n, pre), g) for n, g in [(3, 6), (5, 4), (2, 7)]]

    def run(prefix_cache):
        eng = ContinuousEngine(CFG, params, n_pages=24, page_size=16,
                               max_batch=2, max_len=48,
                               prefill_context="pages",
                               prefix_cache=prefix_cache)
        outs = []
        for p, g in reqs:                      # sequential: each request
            rid = eng.submit(p, g)             # retires before the next
            outs.append(eng.run()[rid])        # arrives, so its prefix is
        return outs, eng                       # published for the next

    cold, _ = run(False)
    hot, eng = run(True)
    assert eng.scheduler.prefix.hits == len(reqs) - 1
    assert eng.scheduler.prefix.hit_tokens == 32 * (len(reqs) - 1)
    assert eng.prefill_tokens_computed \
        == sum(p.size for p, _ in reqs) - 32 * (len(reqs) - 1)
    for a, b in zip(cold, hot):
        np.testing.assert_array_equal(a, b)
    hot2, _ = run(True)                        # and the hit path is
    for a, b in zip(hot, hot2):                # deterministic
        np.testing.assert_array_equal(a, b)


def test_engine_hit_logical_kv_matches_cold_path():
    """gather_request oracle: after the same number of generated
    tokens, a hit request's logical KV -- its pages read back in
    page-table order -- is BITWISE the cold path's.  (Preamble rows
    attend only to preamble slots, so the shared pages a previous
    request wrote are exactly the pages this prompt would have
    written.)"""
    params = _params()
    pre = RNG.integers(0, CFG.vocab, (32,)).astype(np.int32)
    prompt = _shared(4, pre)

    def kv_after(prefix_cache, publish_first):
        eng = ContinuousEngine(CFG, params, n_pages=24, page_size=16,
                               max_batch=2, max_len=48,
                               prefill_context="pages",
                               prefix_cache=prefix_cache)
        if publish_first:                      # cache the preamble pages
            eng.submit(np.concatenate([pre, np.full(2, 9, np.int32)]), 3)
            eng.run()
        rid = eng.submit(prompt, 6)
        while True:
            eng.step()
            req = next(r for r in eng.scheduler.running if r.rid == rid)
            if len(req.generated) == 5:        # stop mid-flight, pages live
                break
        n = req.position + 1                   # live KV slots
        gathered = eng.pool.gather_request(req.pages)
        return ({k: np.asarray(v[:, :, :n]) for k, v in gathered.items()},
                req)

    hot, req = kv_after(True, True)
    assert req.cached_tokens == 32             # really served off a hit
    cold, _ = kv_after(False, False)
    for key in cold:
        np.testing.assert_array_equal(hot[key], cold[key])


def test_engine_prefix_churn_no_leaks_and_deterministic():
    """A starved pool under shared-preamble traffic: sharing, eviction
    and preemption interleave.  The run must stay deterministic, the
    refcount asserts must never fire, and after draining, the pool must
    hold EXACTLY the index's cached pages (each at refcount 1) -- no
    page leaked, none freed twice."""
    params = _params()
    pre = RNG.integers(0, CFG.vocab, (16,)).astype(np.int32)
    reqs = [(_shared(n, pre), g)
            for n, g in [(6, 10), (9, 8), (4, 12), (11, 6)]]

    def run():
        eng = ContinuousEngine(CFG, params, n_pages=5, page_size=8,
                               max_batch=4, max_len=40,
                               prefill_chunk_tokens=8, prefix_cache=True)
        rids = [eng.submit(p, g) for p, g in reqs]
        out = eng.run()
        return [out[r] for r in rids], eng

    a, eng = run()
    b, _ = run()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    sched = eng.scheduler
    assert sched.preemption_count + sched.prefix.evictions > 0
    assert eng.pool.used_pages == len(sched.prefix)
    assert sorted(sched.prefix.cached_pages) == sorted(eng.pool._allocated)
    assert all(eng.pool.refcount(p) == 1
               for p in sched.prefix.cached_pages)
    n = len(sched.prefix)
    assert sched.prefix.evict(n + 5) == n      # only refcount-0... -1 left
    assert eng.pool.used_pages == 0            # everything accounted for


def test_engine_prefix_cache_requires_pages_context():
    params = _params()
    with pytest.raises(ValueError, match="pages"):
        ContinuousEngine(CFG, params, n_pages=8, page_size=16,
                         max_batch=2, max_len=32,
                         prefill_context="carry", prefix_cache=True)
    eng = ContinuousEngine(CFG, params, n_pages=8, page_size=16,
                           max_batch=2, max_len=32, prefix_cache=True)
    assert eng.prefill_context == "pages"      # the prefix-cache default


def test_engine_unaligned_max_len_is_actionable_value_error():
    """REGRESSION: launch/serve.py --continuous --page-size 16 with the
    default --prompt-len/--steps used to die on a bare assert here
    (max_len 56 % 16 != 0); now it is a ValueError that says what to
    do (and the CLI rounds max_len up before it ever gets here)."""
    params = _params()
    with pytest.raises(ValueError, match="round max_len up to 64"):
        ContinuousEngine(CFG, params, n_pages=8, page_size=16,
                         max_batch=2, max_len=56)
