"""N-D (scan/expert-stacked) packed weights: the serving plane for MoE
and scan-over-layers models."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.policy import PrecisionPolicy, flatten_with_paths
from repro.kernels import ops
from repro.models import zoo
from repro.configs import get_config

RNG = np.random.default_rng(0)


def test_pack_tensor_3d_roundtrip():
    w = jnp.asarray(RNG.normal(size=(5, 64, 96)).astype(np.float32))
    t = ops.pack_tensor(F.POSIT8, w)
    assert t.words.shape == (5, 64, 24)          # 96 / 4-per-word
    assert t.scales.shape == (5, 1, 96)
    d = ops.to_dense(t)
    assert d.shape == w.shape
    rel = float(jnp.linalg.norm(d - w) / jnp.linalg.norm(w))
    assert rel < 0.02, rel


def test_pack_tensor_3d_slices_match_2d():
    """lax.scan-style slicing of a stacked PackedTensor's leaves gives the
    same decode as packing each slice alone."""
    w = jnp.asarray(RNG.normal(size=(3, 32, 128)).astype(np.float32))
    t3 = ops.pack_tensor(F.FP4, w, per_channel=False)
    for i in range(3):
        sl = jax.tree.map(lambda x: x[i], t3)
        d = ops.to_dense(sl)
        # same grid: quantize slice directly with the same scale
        from repro.core import quant
        q = quant.fake_quant(F.FP4, w[i], scale=t3.scales[i, 0, 0])
        np.testing.assert_allclose(np.asarray(d), np.asarray(q),
                                   rtol=1e-6, atol=1e-6)


def test_pack_params_only_weights():
    """Biases / norms / states never get packed even when stacked 2-D."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    packed = zoo.pack_params(params, PrecisionPolicy.paper_mixed())
    from repro.kernels.ops import PackedTensor

    bad = []
    def walk(node, path=""):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}/{k}")
        elif isinstance(node, PackedTensor):
            if not (path.endswith("/w") or "experts" in path):
                bad.append(path)
    walk(packed)
    assert not bad, bad


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "jamba-v0.1-52b"])
def test_packed_moe_forward(arch):
    """A packed-expert MoE model still runs forward + decode (the ref
    serving plane), close to the dense model."""
    cfg = get_config(arch).reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    packed = zoo.pack_params(params, PrecisionPolicy.uniform("posit8_0"))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    l_dense, _, _ = zoo.apply_model(params, batch, cfg)
    l_pack, _, _ = zoo.apply_model(packed, batch, cfg)
    pd = jax.nn.softmax(l_dense.astype(jnp.float32), -1)
    pp = jax.nn.softmax(l_pack.astype(jnp.float32), -1)
    assert float(jnp.max(jnp.abs(pd - pp))) < 0.15
    cache = zoo.init_cache(cfg, 2, 32)
    lg, _ = zoo.decode_model(packed, jnp.zeros((2, 1), jnp.int32), cfg,
                             cache, jnp.int32(0))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
