"""Per-architecture smoke tests (assignment requirement): a REDUCED
config of the same family runs one forward/train step + one decode step
on CPU, asserting output shapes and no NaNs.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

B, S = 2, 64


def _batch_for(cfg):
    batch = {"labels": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jnp.asarray(
            np.random.default_rng(1).normal(size=(B, S, cfg.d_model)) * 0.02,
            jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (B, S)),
            jnp.int32)
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jnp.asarray(
                np.random.default_rng(3).normal(
                    size=(B, cfg.n_patches, cfg.d_model)) * 0.02,
                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)

    # forward + loss
    logits, _, aux = T.lm_apply(params, batch, cfg, mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, (ce, _) = T.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))

    # one train (grad) step
    g = jax.grad(lambda p: T.lm_loss(p, batch, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0

    # one decode step with a cache
    cache = T.init_cache(cfg, B, 128)
    logits2, cache2 = T.lm_decode(
        params, jnp.zeros((B, 1), jnp.int32), cfg, cache, jnp.int32(3))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_configs_match_assignment(arch):
    """Exact figures from the assignment table."""
    cfg = get_config(arch)
    expect = {
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (arch, got, expect)


def test_moe_specifics():
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.n_experts, kimi.experts_per_tok) == (384, 8)
    arctic = get_config("arctic-480b")
    assert (arctic.n_experts, arctic.experts_per_tok) == (128, 2)
    assert arctic.dense_residual
    jamba = get_config("jamba-v0.1-52b")
    assert (jamba.n_experts, jamba.experts_per_tok) == (16, 2)
    assert jamba.attn_every == 8 and jamba.moe_every == 2  # 1:7 interleave


def test_param_counts_plausible():
    """Analytic parameter counts should be in the advertised ballpark."""
    import repro.roofline.analysis as ra
    checks = {
        "gemma-2b": (2.0e9, 3.5e9),
        "deepseek-67b": (60e9, 72e9),
        "command-r-plus-104b": (95e9, 115e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.25e12),
        "arctic-480b": (420e9, 530e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params far below total
    kimi = get_config("kimi-k2-1t-a32b")
    act = ra.active_param_count(kimi)
    assert act < 0.06 * kimi.param_count()


def test_long_500k_skips_are_correct():
    from repro.configs import all_cells
    skipped = {(a, s) for a, s, _, _, ok in all_cells() if not ok}
    assert all(s == "long_500k" for _, s in skipped)
    runnable_500k = {a for a, s, _, _, ok in all_cells()
                     if s == "long_500k" and ok}
    assert runnable_500k == {"rwkv6-1.6b", "jamba-v0.1-52b"}
