"""Pipeline parallelism (GPipe over a mesh axis) == sequential semantics.
Runs in a subprocess with 8 fake devices (same pattern as test_sharding)."""

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pipeline_matches_sequential():
    code = textwrap.dedent("""
        import json, jax, numpy as np
        import jax.numpy as jnp
        def _mk(shape, axes):
            try:
                return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
            except (AttributeError, TypeError):
                return jax.make_mesh(shape, axes)
        mesh = _mk((4, 2), ("stage", "data"))
        from repro.parallel.pipeline import pipeline_apply

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"]) + p["b"]

        rng = np.random.default_rng(0)
        S, D = 4, 16
        params = {
            "w": jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3),
            "b": jnp.asarray(rng.normal(size=(S, 1, D)).astype(np.float32) * 0.1),
        }
        x = jnp.asarray(rng.normal(size=(8, D)).astype(np.float32))

        # sequential reference
        y_ref = x
        for s in range(S):
            y_ref = stage_fn(jax.tree.map(lambda t: t[s], params), y_ref)

        y_pipe = pipeline_apply(mesh, "stage", stage_fn, params, x,
                                n_microbatches=4)
        err = float(jnp.max(jnp.abs(y_pipe - y_ref)))
        print(json.dumps({"err": err}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.splitlines()[-1])
    assert res["err"] < 1e-5, res
