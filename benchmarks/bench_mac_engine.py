"""Table II analogue -- the SIMD MAC compute engine.

The ASIC table reports freq/area/power/arithmetic-intensity; the
software-visible analogues here are throughput of the packed GEMM path
and the *memory-traffic reduction* of the packed formats (bytes per
operand), which is where the paper's 2.85x arithmetic-intensity gain
comes from.  Runs the pure-jnp RMMEC path (the Pallas kernel itself is
validated in interpret mode by tests; wall-clock on CPU interpret mode
is not meaningful)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.kernels import ops, ref
from .common import emit, time_call

M, K, N = 128, 1024, 1024


def run() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    dense_bytes = K * N * 4
    flops = 2 * M * K * N

    f = jax.jit(lambda x, w: x @ w)
    us = time_call(f, x, w)
    emit("mac_engine/fp32_dense", us,
         f"bytes_w={dense_bytes};AI={flops/ (dense_bytes + M*K*4):.2f}")

    # group-size axis: None = per-channel (the seed configuration whose
    # throughput must not regress), 64/32 = finer dequant-scale groups
    # along K (more scale traffic, better accuracy -- see bench_accuracy)
    for group in (None, 64, 32):
        for spec in (F.POSIT16, F.POSIT8, F.POSIT4, F.FP4):
            t = ops.pack_tensor(spec, w, group_size=group)
            pm = jax.jit(lambda x, t: ops.packed_matmul(x, t, use_ref=True))
            us = time_call(pm, x, t)
            pbytes = t.words.size * 4 + t.scales.size * 4
            ai_gain = dense_bytes / pbytes
            lanes = F.simd_lanes(spec)
            gtag = "" if group is None else f"_g{group}"
            emit(f"mac_engine/packed_{spec.name}{gtag}", us,
                 f"bytes_w={pbytes};AI_gain_vs_fp32={ai_gain:.2f};"
                 f"simd_lanes_16b={lanes}")

    # quire-exact posit8 accumulation vs naive f32 ordering
    a = jnp.asarray(rng.integers(0, 256, size=(64, 1024)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, size=(64, 1024)), jnp.int32)
    qd = jax.jit(ops.quire_dot)
    us = time_call(qd, a, b)
    emit("mac_engine/quire_dot_posit8", us, "exact=1;limbs=int32x2")
