"""Table IV analogue -- end-to-end co-processor vs SoTA.

The ASIC table reports accuracy + energy-efficiency + compute density per
accelerator.  Software analogue: end-to-end inference of the serving
plane (packed mixed-precision weights) vs the fp32 dense plane on the
same model: wall time, weight bytes (the energy proxy: off-chip movement
is ~60% of system energy per the paper), and output agreement."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import PrecisionPolicy, flatten_with_paths
from repro.models import zoo
from .common import emit, time_call


def run() -> None:
    cfg = get_config("qwen2-0.5b").reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (4, 64)), jnp.int32)}

    dense_bytes = sum(int(np.prod(l.shape)) * 4
                      for _, l in flatten_with_paths(params))
    f_dense = jax.jit(lambda p, b: zoo.apply_model(p, b, cfg)[0])
    us_dense = time_call(f_dense, params, batch)
    emit("e2e/fp32_dense", us_dense, f"weight_bytes={dense_bytes}")

    ref_logits = f_dense(params, batch)
    for pol_name, pol in (
            ("posit8", PrecisionPolicy.uniform("posit8_0")),
            ("mxp_paper", PrecisionPolicy.paper_mixed())):
        packed = zoo.pack_params(params, pol)
        pbytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for _, l in flatten_with_paths(packed))
        f_packed = jax.jit(lambda p, b: zoo.apply_model(p, b, cfg)[0])
        us = time_call(f_packed, packed, batch)
        lg = f_packed(packed, batch)
        pd = jax.nn.softmax(ref_logits.astype(jnp.float32), -1)
        pp = jax.nn.softmax(lg.astype(jnp.float32), -1)
        tv = float(0.5 * jnp.mean(jnp.sum(jnp.abs(pd - pp), -1)))
        emit(f"e2e/packed_{pol_name}", us,
             f"weight_bytes={pbytes};traffic_gain={dense_bytes/pbytes:.2f};"
             f"tv_dist={tv:.4f}")
