"""Model-size table: the paper's 13.5 MB (FP32) -> 3.4 (FP8/INT8) ->
3.6 (Posit8/16) -> 2.42 MB (HFP4/Posit4/Posit8 mixed) UL-VIO story,
reproduced with our policy machinery on the UL-VIO-sized model."""

from __future__ import annotations

import jax

from repro.core.policy import PrecisionPolicy
from repro.models import perception as P
from .common import emit


def run() -> None:
    # width chosen so fp32 lands near the paper's 13.5 MB UL-VIO figure
    params = P.vio_init(jax.random.PRNGKey(0), feat_dim=1024, width=1024)
    rows = [
        ("fp32", PrecisionPolicy.uniform("fp32")),
        ("fp8", PrecisionPolicy.uniform("fp8_e4m3")),
        ("posit8", PrecisionPolicy.uniform("posit8_0")),
        ("posit16", PrecisionPolicy.uniform("posit16_1")),
        ("mxp_hfp4_posit", PrecisionPolicy.paper_mixed()),
        ("fp4", PrecisionPolicy.uniform("fp4")),
    ]
    base = rows[0][1].model_bytes(params)
    for name, pol in rows:
        b = pol.model_bytes(params)
        emit(f"model_size/{name}", 0.0,
             f"mb={b/1e6:.2f};ratio_vs_fp32={base/b:.2f}")
