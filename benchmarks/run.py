# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one harness per paper table/figure.

  Table II  -> bench_mac_engine     (SIMD MAC engine, packed GEMM + quire)
  Table III -> bench_coprocessor    (morphable 8x8/16x16 array)
  Table IV  -> bench_e2e            (end-to-end packed vs dense serving)
  Fig 5-8   -> bench_accuracy       (precision sweeps on the XR workloads)
  size tbl  -> bench_model_size     (13.5 -> 2.42 MB UL-VIO story)
  decode    -> bench_decode         (quantized-KV flash decode vs bf16
                                     cache: tokens/s + KV bytes/step)
  serve     -> bench_serve          (continuous batching over paged KV:
                                     throughput, p50/p99 latency, pool
                                     utilization vs static max_len waste)

Roofline terms for the assigned architectures come from the dry-run
(launch/dryrun.py), not from CPU wall-clock -- see EXPERIMENTS.md.
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single bench (mac_engine|coprocessor|"
                         "e2e|accuracy|model_size|decode|serve)")
    args = ap.parse_args()
    from . import (bench_accuracy, bench_coprocessor, bench_decode,
                   bench_e2e, bench_mac_engine, bench_model_size,
                   bench_serve)
    benches = {
        "mac_engine": bench_mac_engine.run,
        "coprocessor": bench_coprocessor.run,
        "e2e": bench_e2e.run,
        "model_size": bench_model_size.run,
        "accuracy": bench_accuracy.run,
        "decode": bench_decode.run,
        "serve": bench_serve.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
