"""Fig. 5/6/7/8 analogues -- application accuracy vs precision.

Trains the paper's three XR perception workloads (object classification,
UL-VIO, eye-gaze) briefly on CPU, then evaluates each under the precision
sweep FP32 / Posit16 / Posit8 / FP8 / FP4 / Posit4 / MxP (the paper's
layer-adaptive mixture), both post-training (PTQ) and with the eq.1-2
adaptive policy.  Output: one CSV row per (task, precision)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import format_by_name as fmt_by_name
from repro.core.policy import PrecisionPolicy
from repro.core.qat import quantize_tree
from repro.core.sensitivity import assign_layer_adaptive
from repro.data.vio_data import VIOStream
from repro.models import perception as P
from .common import emit, time_call

SWEEP = ["fp32", "posit16_1", "posit8_0", "fp8_e4m3", "fp4", "posit4_1"]


def _policy(name, params=None, grads=None):
    if name == "mxp_adaptive":
        return assign_layer_adaptive(params, grads, target_avg_bits=6.0)
    if name == "mxp_paper":
        return PrecisionPolicy.paper_mixed()
    return PrecisionPolicy.uniform(name)


def _train(loss_fn, params, batches, lr=1e-3, steps=250):
    from repro.optim import OptConfig, adamw_init, adamw_update
    ocfg = OptConfig(weight_decay=0.0)
    ost = adamw_init(params, ocfg)

    @jax.jit
    def step(p, ost, b):
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        p, ost = adamw_update(p, g, ost, lr, ocfg)
        return p, ost, m
    for i in range(steps):
        params, ost, m = step(params, ost, batches(i))
    return params, m


def run() -> None:
    rng = np.random.default_rng(0)

    # ---- Fig. 5: object classification ---------------------------------
    # harder-than-separable regime (noise ~ 1.4x template energy) so the
    # precision sweep shows the paper's degradation ordering
    templates = rng.normal(size=(10, 16, 16, 3)).astype(np.float32)

    def cls_batch(i, n=64):
        r = np.random.default_rng(i)
        y = r.integers(0, 10, n)
        x = templates[y] + r.normal(size=(n, 16, 16, 3)) * 1.4
        return {"images": jnp.asarray(x, jnp.float32),
                "labels": jnp.asarray(y)}

    cparams, _ = _train(P.classifier_loss,
                        P.classifier_init(jax.random.PRNGKey(1), width=8),
                        cls_batch, lr=3e-3, steps=200)
    test_b = cls_batch(10_001, 512)
    cal_g = jax.grad(lambda p: P.classifier_loss(p, test_b)[0])(cparams)
    for prec in SWEEP + ["mxp_paper", "mxp_adaptive"]:
        pol = _policy(prec, cparams, cal_g)
        q = quantize_tree(cparams, pol)
        _, m = P.classifier_loss(q, test_b)
        emit(f"accuracy/classify_{prec}", 0.0,
             f"acc={float(m['acc']):.4f};avg_bits={pol.average_bits(cparams):.2f}")

    # ---- Fig. 6: UL-VIO --------------------------------------------------
    stream = VIOStream(batch=64)

    def vio_batch(i):
        return {k: jnp.asarray(v) for k, v in stream.next_batch().items()}

    vparams, _ = _train(P.vio_loss, P.vio_init(jax.random.PRNGKey(2)),
                        vio_batch, lr=1e-3, steps=300)
    vb = vio_batch(0)
    cal_g = jax.grad(lambda p: P.vio_loss(p, vb)[0])(vparams)
    base = None
    for prec in SWEEP + ["mxp_paper", "mxp_adaptive"]:
        pol = _policy(prec, vparams, cal_g)
        q = quantize_tree(vparams, pol)
        _, m = P.vio_loss(q, vb)
        t, r = float(m["t_rmse"]), float(m["r_rmse"])
        if prec == "fp32":
            base = (t, r)
        emit(f"accuracy/vio_{prec}", 0.0,
             f"t_rmse={t:.4f};r_rmse={r:.4f};"
             f"dt_pp={100*(t-base[0]):.2f};dr_pp={100*(r-base[1]):.2f};"
             f"bytes={pol.model_bytes(vparams)}")

    # ---- group-size axis: weight-grid error of the packed plane ---------
    # The per-group (block-wise) scale is the accuracy lever for the
    # 4-bit formats: finer K-groups track local dynamic range one
    # per-channel scale cannot.  Measured on the *trained* VIO weights
    # (heterogeneous rows -- the regime where grouping pays).
    from repro.core.policy import flatten_with_paths
    from repro.kernels import ops as kops
    mats = [leaf for path, leaf in flatten_with_paths(vparams)
            if getattr(leaf, "ndim", 0) == 2 and leaf.shape[0] >= 64]
    for prec in ("fp4", "posit4_1"):
        spec = fmt_by_name(prec)
        for group in (None, 128, 64, 32):
            # accumulate the squared errors as device scalars and sync
            # ONCE after the loop -- float() per matrix blocked on a
            # device round trip every iteration
            num_d, den_d = [], []
            for wmat in mats:
                d = kops.to_dense(kops.pack_tensor(spec, wmat,
                                                   group_size=group))
                num_d.append(jnp.sum(jnp.square(d - wmat)))
                den_d.append(jnp.sum(jnp.square(wmat)))
            num, den = jax.device_get((sum(num_d), sum(den_d)))
            rel = float(np.sqrt(num / max(float(den), 1e-30)))
            gtag = "chan" if group is None else f"g{group}"
            emit(f"accuracy/group_scale_{prec}_{gtag}", 0.0,
                 f"w_rel_rmse={rel:.5f};n_mats={len(mats)}")

    # ---- Fig. 7: eye gaze -----------------------------------------------
    wtrue = rng.normal(size=(128, 2)).astype(np.float32) * 0.3

    def gaze_batch(i, n=64):
        r = np.random.default_rng(1000 + i)
        f = r.normal(size=(n, 128)).astype(np.float32)
        y = f @ wtrue + r.normal(size=(n, 2)).astype(np.float32) * 0.05
        return f, y

    gparams = P.gaze_init(jax.random.PRNGKey(3))

    def gaze_loss(p, b):
        f, y = b
        pred = P.gaze_apply(p, jnp.asarray(f))
        mse = jnp.mean(jnp.square(pred - jnp.asarray(y)))
        return mse, {"mse": mse}

    gparams, _ = _train(gaze_loss, gparams,
                        lambda i: gaze_batch(i), lr=3e-3, steps=200)
    gb = gaze_batch(99, 512)
    for prec in SWEEP:
        q = quantize_tree(gparams, _policy(prec))
        _, m = gaze_loss(q, gb)
        emit(f"accuracy/gaze_{prec}", 0.0, f"mse={float(m['mse']):.5f}")
