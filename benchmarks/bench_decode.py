"""Decode-plane benchmark: quantized-KV flash decode vs the bf16 cache.

The decode roofline is KV + weight bytes per step.  This harness
measures, on the same model and prompt:

  * tokens/s of ``ServeEngine.generate`` with a bf16 KV cache (baseline)
    vs the posit8 quantized cache (per-(token,head) and Dh-grouped
    scales) -- the end-to-end serving numbers;
  * per-call time of the fused Pallas flash-decode kernel vs the
    pure-XLA blocked fallback on one attention layer's worth of cache;
  * MODELED KV bytes/step (``roofline.analysis.decode_kv_bytes``): the
    quantized cache must move >= 2x fewer bytes than bf16, and the
    length-aware path must not scale with ``max_len`` when
    ``pos << max_len`` (the two acceptance claims of the KV plane).

Results go to stdout as the usual ``name,us_per_call,derived`` CSV and
to BENCH_decode.json at the repo root (the perf-trajectory artifact CI
refreshes via ``--smoke``).

  PYTHONPATH=src python -m benchmarks.bench_decode [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import PrecisionPolicy
from repro.kernels.flash_decode import default_kv_block, flash_decode_pallas
from repro.models import attention as A
from repro.models import zoo
from repro.obs.stats import time_call
from repro.roofline.analysis import decode_kv_bytes
from repro.serve.engine import ServeEngine
from .common import emit

OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_decode.json")


def _engine_tokens_per_s(cfg, params, toks, steps, max_len, quantized_kv,
                         policy=None):
    eng = ServeEngine(cfg, params, max_len=max_len,
                      quantized_kv=quantized_kv, policy=policy)
    eng.generate(toks, steps=2)                      # warm the jit caches
    t0 = time.perf_counter()
    out = eng.generate(toks, steps=steps)
    dt = time.perf_counter() - t0
    assert np.isfinite(out).all()
    return toks.shape[0] * steps / dt


def _kernel_vs_blocked(cfg, max_len, pos):
    """Per-call time of the fused kernel vs the XLA fallback on one
    layer's cache (both jitted; CPU runs the kernel in interpret)."""
    rng = np.random.default_rng(0)
    b, kh, dh = 2, cfg.n_kv_heads, cfg.resolved_head_dim
    g = cfg.n_heads // cfg.n_kv_heads
    q = jnp.asarray(rng.normal(size=(b, kh, g, dh)).astype(np.float32))
    kv = rng.normal(size=(2, b, max_len, kh, dh)).astype(np.float32)
    kc, ks = A.quantize_kv(jnp.asarray(kv[0]))
    vc, vs = A.quantize_kv(jnp.asarray(kv[1]))
    cache = {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}
    interpret = jax.default_backend() != "tpu"

    f_flash = jax.jit(lambda *a: flash_decode_pallas(
        *a, interpret=interpret))
    f_block = jax.jit(lambda q_, c_, p_: A.decode_quantized_blocks(q_, c_, p_))
    pos_j = jnp.int32(pos)
    us_f = time_call(f_flash, q, kc, ks, vc, vs, pos_j)
    us_b = time_call(f_block, q, cache, pos_j)
    np.testing.assert_allclose(
        np.asarray(f_flash(q, kc, ks, vc, vs, pos_j)),
        np.asarray(f_block(q, cache, pos_j)), rtol=1e-4, atol=1e-4)
    return us_f, us_b


def run(smoke: bool = False) -> None:
    cfg = get_config("qwen2-0.5b").reduced()
    max_len = 256 if smoke else 1024
    steps = 8 if smoke else 32
    prompt = 8
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, prompt)), jnp.int32)
    results = {"config": {"arch": cfg.name, "max_len": max_len,
                          "steps": steps, "backend": jax.default_backend()}}

    # --- end-to-end serving: bf16 KV vs posit8 KV (per-head + grouped)
    tps = {}
    tps["bf16_kv"] = _engine_tokens_per_s(cfg, params, toks, steps, max_len,
                                          quantized_kv=False)
    tps["posit8_kv"] = _engine_tokens_per_s(cfg, params, toks, steps, max_len,
                                            quantized_kv=True)
    grp = PrecisionPolicy(rules=[], default="fp32",
                          group_size=cfg.resolved_head_dim // 2)
    tps["posit8_kv_grouped"] = _engine_tokens_per_s(
        cfg, params, toks, steps, max_len, quantized_kv=True, policy=grp)
    for name, v in tps.items():
        emit(f"decode/generate_{name}", 1e6 / max(v, 1e-9),
             f"tokens_per_s={v:.1f}")
    results["tokens_per_s"] = tps

    # --- fused kernel vs XLA blocked fallback, one layer
    pos = prompt + steps
    us_f, us_b = _kernel_vs_blocked(cfg, max_len, pos)
    emit("decode/flash_kernel_layer", us_f, f"pos={pos};max_len={max_len}")
    emit("decode/blocked_xla_layer", us_b, f"pos={pos};max_len={max_len}")
    results["kernel_us"] = {"flash": us_f, "blocked": us_b}

    # --- modeled KV bytes/step: the two roofline claims
    b = int(toks.shape[0])
    blk = default_kv_block(max_len)
    bytes_bf16 = decode_kv_bytes(cfg, b, max_len, pos, quantized=False)
    bytes_q_full = decode_kv_bytes(cfg, b, max_len, pos, quantized=True,
                                   length_aware=False)
    bytes_q = decode_kv_bytes(cfg, b, max_len, pos, quantized=True, blk=blk)
    bytes_q_8x = decode_kv_bytes(cfg, b, 8 * max_len, pos, quantized=True,
                                 blk=blk)
    ratio = bytes_bf16 / bytes_q
    emit("decode/kv_bytes_per_step", 0.0,
         f"bf16={bytes_bf16:.0f};posit8_full={bytes_q_full:.0f};"
         f"posit8_lenaware={bytes_q:.0f};gain={ratio:.2f}x")
    assert bytes_bf16 >= 2 * bytes_q, \
        "quantized KV decode must move >=2x fewer bytes than the bf16 path"
    assert bytes_q == bytes_q_8x, \
        "length-aware decode must not scale with max_len when pos << max_len"
    results["kv_bytes_per_step"] = {
        "bf16_full": bytes_bf16, "posit8_full": bytes_q_full,
        "posit8_lenaware": bytes_q,
        "posit8_lenaware_8x_maxlen": bytes_q_8x,
        "gain_vs_bf16": ratio, "block": blk, "pos": pos,
    }

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(OUT_JSON)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few steps (the CI invocation)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
