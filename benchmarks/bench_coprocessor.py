"""Table III analogue -- the morphable matrix-multiplication co-processor.

The FPGA table reports LUT/FF/DSP/GOPS/W at iso-compute (64 MACs); the
software analogues: throughput of the morphable-array GEMM at the 8x8 and
16x16 array configurations (= block tilings), per precision mode, plus
packed-traffic at each mode.  Derived fields carry the iso-compute
comparison the paper makes (1.4x LUT / 1.77x FF are silicon; the
traffic ratio is what survives the port)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.kernels import ops
from .common import emit, time_call


def run() -> None:
    rng = np.random.default_rng(0)
    M, K, N = 64, 512, 512
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    flops = 2 * M * K * N

    for arr, (bm, bk, bn) in (("8x8", (8, 512, 128)),
                              ("16x16", (16, 512, 128))):
        for spec in (F.FP4, F.POSIT8, F.POSIT16):
            t = ops.pack_tensor(spec, w, blocks=(bm, bk, bn))
            f = jax.jit(lambda x, t: ops.packed_matmul(
                x, t, use_ref=True))
            us = time_call(f, x, t)
            gops = flops / (us * 1e-6) / 1e9
            emit(f"coprocessor/array{arr}_{spec.name}", us,
                 f"gops={gops:.2f};packed_bytes={t.words.size*4};"
                 f"mode=prec_sel_{F.simd_lanes(spec)}lane")
