"""Serving-plane benchmark: continuous batching over the paged KV pool
vs static batching.

A staggered-arrival trace of mixed-length requests is served by the
``ContinuousEngine`` while the harness records, per engine step, the
wall time, the live page count and the per-request positions.  It
reports:

  * throughput (generated tokens / wall second) for the continuous
    engine vs one static left-padded ``ServeEngine`` batch that can only
    start when ALL requests have arrived and must decode until the
    LONGEST one finishes;
  * request latency (arrival -> retirement wall time): p50 / p99;
  * page-pool utilization (mean / peak over steps) vs the static plan's
    ``batch * max_len`` slot reservation -- the "max_len waste";
  * MODELED KV bytes/step: paged (live pages of each running request,
    ``serve.paged_kv.paged_kv_bytes_per_step``) vs the static
    length-aware posit8 plan (every row pays the shared front position)
    and the static bf16 full-buffer plan.  The paged number is a
    function of live positions ONLY -- recomputing it under an 8x
    ``max_len`` serving plan must not change a single step (the paged
    acceptance claim; asserted);
  * CHUNKED PREFILL long-prompt latency: a long prompt lands while
    short requests decode; per-engine-step wall time p99 under
    monolithic prefill (the arrival step pays the whole prompt) vs
    chunked prefill (every step pays at most one chunk).  Chunked p99
    must come in below monolithic AND both engines' temperature-0
    outputs must match per-request static ``ServeEngine.generate``
    token for token (asserted -- the chunked-prefill acceptance claim);
  * PREFIX CACHING shared-preamble arrivals: every request opens with
    the same scene preamble (the XR traffic shape); prefill tokens
    computed and time-to-first-token p50/p99 with the copy-on-write
    prefix cache on vs off.  Asserted: temperature-0 outputs match the
    cache-off engine token for token, and requests after the first
    sharer re-prefill at most HALF their prompt (>= 2x fewer prefill
    tokens -- the prefix-caching acceptance claim).

  * DISAGGREGATED prefill/decode: a prefill-burst trace (steady decode
    cohort + periodic long-prompt arrivals) served interleaved vs
    through ``DisaggEngine``.  Per-decoded-step latency p99 of the
    disaggregated DECODE side (dispatch+sync only; the prefill worker
    runs inside the overlap window) must come in at or below the
    interleaved engine's whole-step p99 (asserted -- the decode-
    isolation acceptance claim), outputs must match the static oracle
    token for token, and the measured channel traffic must equal
    ``handoff_pages * page_handoff_bytes`` (the posit8 page model;
    asserted).

  * PAGED STATE (recurrent families): an RWKV cohort served off the
    pool's state-slab plane -- zero KV pages, one posit8 slab per
    request, rewritten in place inside the fused K-step loop.
    Asserted: the ``engine/state_bytes_per_step_model`` gauge equals
    the pool model and the closed form ``2 * state_slab_bytes * live``
    every step, the footprint stays one slab per live request with
    zero pages (constant-footprint admission), zero steady-state
    retraces, and temperature-0 outputs are identical across
    ``decode_steps`` K=1 and K=4.

Results go to stdout as the usual ``name,us_per_call,derived`` CSV and
to BENCH_serve.json at the repo root (CI refreshes it via ``--smoke``);
``scenario_wall_s`` in the JSON records each scenario's harness wall
time.

Serving-plane telemetry (``repro.obs``, PR 8) is exercised throughout:
the continuous scenario's request-latency percentiles are derived from
the lifecycle trace (SUBMIT -> RETIRE stamps) instead of hand-rolled
dicts; the traced decode-loop runs assert the trace's DECODE_DISPATCH
count equals both the engine counter and the ``(gen-1)/K`` closed
form; the disaggregated burst asserts its HANDOFF events mirror the
channel counters exactly and exports a schema-validated Chrome-trace
artifact to ``artifacts/serve_trace.json`` (open in Perfetto).

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import zoo
from repro.obs import TraceRecorder, validate_chrome_trace
from repro.roofline.analysis import decode_kv_bytes
from repro.serve import ContinuousEngine, DisaggEngine, ServeEngine
from repro.serve.paged_kv import (page_handoff_bytes,
                                  paged_kv_bytes_per_step,
                                  state_slab_bytes)
from .common import emit

OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
TRACE_JSON = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "serve_trace.json")


def _trace(cfg, n_req, rng):
    """(arrival_step, prompt, gen) per request: ragged lengths, two
    requests arriving every other engine step."""
    out = []
    for i in range(n_req):
        plen = int(rng.integers(3, 13))
        gen = int(rng.integers(4, 25))
        out.append((i // 2, rng.integers(0, cfg.vocab, (plen,)).astype(
            np.int32), gen))
    return out


def _serve_continuous(cfg, params, trace, n_pages, page_size, max_batch,
                      max_len):
    # lifecycle tracing is ON for this scenario: the request latency
    # percentiles come from the recorder's SUBMIT/RETIRE stamps instead
    # of the hand-rolled arrive/finish dicts this harness used to keep
    # (the recorder stamps SUBMIT inside ``eng.submit`` -- the same
    # instant the old dict recorded)
    rec = TraceRecorder()
    eng = ContinuousEngine(cfg, params, n_pages=n_pages,
                           page_size=page_size, max_batch=max_batch,
                           max_len=max_len, trace=rec)
    # warm the jits (prefill bucket + decode step) off the clock, then
    # RESET the counters: the warm request's pages/steps/preemptions
    # used to leak into the reported peak_pages / engine_steps baseline
    warm = eng.submit(trace[0][1], 2)
    eng.run()
    eng.scheduler.finished.pop(warm)
    eng.reset_counters()
    rec.clear()                    # drop the warm request's events too
    # steady state begins here: decode dispatch+sync run under
    # jax.transfer_guard("disallow") -- an implicit transfer on the
    # decode critical path raises -- and the decode loop must not
    # retrace across churn, preemptions and epoch re-uploads (prefill
    # legitimately traces new chunk-width buckets; decode's shapes are
    # fixed by max_batch)
    eng.transfer_guard = True
    decode_traces0 = eng.trace_counts["decode_loop"]

    pending = sorted(trace, key=lambda t: t[0])
    util, positions_per_step = [], []
    t0 = time.perf_counter()
    rids = {}
    i = 0
    while pending or eng.scheduler.has_work:
        while pending and pending[0][0] <= i:
            _, prompt, gen = pending.pop(0)
            rids[eng.submit(prompt, gen)] = (prompt, gen)
        eng.step()
        # the engine records the positions its decode ACTUALLY served,
        # including requests that retired within the step
        positions_per_step.append(list(eng.last_positions))
        # read through the registry gauge -- same number as
        # ``eng.pool.utilization``, exercising the metrics plane
        util.append(eng.metrics.value("pool/utilization"))
        i += 1
    dt = time.perf_counter() - t0
    decode_retraces = eng.trace_counts["decode_loop"] - decode_traces0
    assert decode_retraces == 0, \
        f"decode loop retraced {decode_retraces}x in steady state"
    toks = sum(len(eng.scheduler.finished[r].generated) for r in rids)
    # per-request SLOs straight from the lifecycle trace; every request
    # must have a complete SUBMIT -> ... -> RETIRE record
    slo = rec.request_slo()
    assert set(slo) == set(rids), (set(slo), set(rids))
    assert rec.count("RETIRE") == len(rids), rec.count("RETIRE")
    lat = np.asarray([slo[r]["e2e_ms"] for r in rids])
    return eng, dict(
        tokens=toks, wall_s=dt, tokens_per_s=toks / dt,
        engine_steps=i,
        latency_p50_ms=float(np.percentile(lat, 50)),
        latency_p99_ms=float(np.percentile(lat, 99)),
        slo_ms=rec.slo_summary(),
        pool_util_mean=float(np.mean(util)),
        pool_util_peak=float(np.max(util)),
        peak_pages=eng.pool.alloc_peak,
        preemptions=eng.scheduler.preemption_count,
        steady_state_retraces=decode_retraces,
    ), positions_per_step


def _serve_long_prompt(cfg, params, page_size, max_len, chunk):
    """A long prompt arrives while short requests decode; returns the
    per-engine-step wall times and every request's output.

    ``chunk=None`` is the monolithic baseline: the arrival step pays the
    whole long prefill and every running decode stalls behind it.  With
    ``chunk`` set, no step pays more than ``chunk`` prefill tokens."""
    rng = np.random.default_rng(3)
    shorts = [(rng.integers(0, cfg.vocab, (6,)).astype(np.int32), 24)
              for _ in range(3)]
    long_req = (rng.integers(0, cfg.vocab, (5 * page_size,)).astype(
        np.int32), 8)
    eng = ContinuousEngine(cfg, params, n_pages=24, page_size=page_size,
                           max_batch=4, max_len=max_len,
                           prefill_chunk_tokens=chunk)

    def drive():
        rids = {}
        for p, g in shorts:
            rids[eng.submit(p, g)] = (p, g)
        steps = []
        k = 0
        while eng.scheduler.has_work:
            if k == 3:   # the long prompt lands mid-decode
                rids[eng.submit(*long_req)] = long_req
            t0 = time.perf_counter()
            eng.step()
            steps.append(time.perf_counter() - t0)
            k += 1
        return rids, steps

    drive()                              # warm every jit shape off-clock
    # the engine is deterministic, so every drive replays the same step
    # sequence: the per-step-index MEDIAN over repeats measures each
    # step's true cost with host-timer spikes (GC etc.) voted out
    reps = []
    for _ in range(3):
        rids, steps = drive()
        reps.append(steps)
    med = np.median(np.asarray(reps), axis=0) * 1e3
    p99 = float(np.percentile(med, 99))
    outs = {r: eng.scheduler.finished[r].output for r in rids}
    return rids, outs, p99


def _serve_disagg_burst(cfg, params, page_size, max_len, disagg):
    """The prefill-burst trace: three short requests decode steadily
    while long prompts keep landing every three steps.  Returns every
    request's output and the per-DECODED-step latencies (median over
    repeats, like ``_serve_long_prompt``).

    The latency being compared is each side's decode critical path.
    For the interleaved engine that is the whole ``step()`` wall time:
    a long prompt's chunk runs INSIDE the step, ahead of the decode
    sync, so the running decoders stall behind it.  For ``DisaggEngine``
    it is ``last_decode_step_s`` -- dispatch + token sync only, because
    the prefill worker runs inside the async overlap window between
    them and never extends the decode path."""
    rng = np.random.default_rng(9)
    shorts = [(rng.integers(0, cfg.vocab, (6,)).astype(np.int32), 24)
              for _ in range(3)]
    longs = [(rng.integers(0, cfg.vocab,
                           (4 * page_size,)).astype(np.int32), 4)
             for _ in range(2)]
    # the disagg side runs TRACED (handoff/dispatch events feed the
    # tie-out asserts and the exported artifact); the interleaved side
    # runs untraced, so the shared static-oracle parity check below
    # doubles as the tracing-changes-no-math check
    rec = TraceRecorder() if disagg else None
    if disagg:
        eng = DisaggEngine(cfg, params, prefill_pages=24, decode_pages=24,
                           page_size=page_size, max_batch=4,
                           max_len=max_len,
                           prefill_chunk_tokens=page_size, trace=rec)
    else:
        eng = ContinuousEngine(cfg, params, n_pages=24,
                               page_size=page_size, max_batch=4,
                               max_len=max_len,
                               prefill_chunk_tokens=page_size)

    def drive():
        rids = {}
        for p, g in shorts:
            rids[eng.submit(p, g)] = (p, g)
        lat = []
        pend = list(longs)
        k = 0
        while pend or (eng.has_work if disagg
                       else eng.scheduler.has_work):
            # long prompt i lands at step 3 * (i + 1), mid-decode
            if pend and k >= 3 * (len(longs) - len(pend) + 1):
                p, g = pend.pop(0)
                rids[eng.submit(p, g)] = (p, g)
            t0 = time.perf_counter()
            n = eng.step()
            dt = eng.last_decode_step_s if disagg \
                else time.perf_counter() - t0
            if n:                      # steps that served a decode
                lat.append(dt)
            k += 1
        return rids, lat

    drive()                            # warm every jit shape off-clock
    # the decode side's steady state starts now: guard its dispatch+
    # sync windows and pin zero decode-loop retraces across the replays
    # (handoffs re-key the page-table epoch every admission -- exactly
    # the churn the sentinel must stay flat under)
    if disagg:
        eng.decode.transfer_guard = True
        decode_traces0 = eng.decode.trace_counts["decode_loop"]
    else:
        eng.transfer_guard = True
        decode_traces0 = eng.trace_counts["decode_loop"]
    reps = []                          # deterministic replay: the per-
    for _ in range(3):                 # step-index median votes out
        rids, lat = drive()            # host-timer spikes
        reps.append(lat)
    counts = eng.decode.trace_counts if disagg else eng.trace_counts
    assert counts["decode_loop"] == decode_traces0, \
        (counts["decode_loop"], decode_traces0)
    med = np.median(np.asarray(reps), axis=0) * 1e3
    fin = eng.finished if disagg else eng.scheduler.finished
    outs = {r: fin[r].output for r in rids}
    return eng, rids, outs, float(np.percentile(med, 99)), rec


def _preamble_trace(cfg, rng, n_req, pre_tokens, arrival_gap):
    """(arrival_step, prompt, gen) per request: every prompt opens with
    the SAME ``pre_tokens``-long preamble (the XR scene/system prompt
    ahead of every VIO / gaze query) followed by a short unique tail.
    ``arrival_gap`` steps separate arrivals -- at least the first
    sharer's chunked-prefill step count, so its preamble pages are
    published before the next request is admitted and every request
    after the first is a cache hit."""
    pre = rng.integers(0, cfg.vocab, (pre_tokens,)).astype(np.int32)
    out = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.integers(2, 6)),)).astype(np.int32)
        out.append((i * arrival_gap, np.concatenate([pre, tail]),
                    int(rng.integers(4, 10))))
    return out


def _serve_shared_preamble(cfg, params, trace, n_pages, page_size,
                           max_batch, max_len, prefix_cache):
    """Serve the shared-preamble trace; returns per-rid outputs + stats.

    BOTH the cache-on and the cache-off engine run
    ``prefill_context='pages'``: a hit's remaining chunks attend to the
    preamble through the same posit8 page reads a cold run performs,
    and the shared pages hold bitwise the codes the cold run would have
    written -- that is what makes temperature-0 parity exact."""
    eng = ContinuousEngine(cfg, params, n_pages=n_pages,
                           page_size=page_size, max_batch=max_batch,
                           max_len=max_len, prefill_chunk_tokens=page_size,
                           prefill_context="pages",
                           prefix_cache=prefix_cache)
    # warm the jits with a SUB-PAGE prompt: it completes no whole prompt
    # page, so the warm request seeds no reusable prefix either way
    warm = eng.submit(trace[0][1][:3], 2)
    eng.run()
    eng.scheduler.finished.pop(warm)
    eng.reset_counters()

    pending = sorted(trace, key=lambda t: t[0])
    arrive, first_tok, rids = {}, {}, {}
    i = n_retired = 0
    while pending or eng.scheduler.has_work:
        while pending and pending[0][0] <= i:
            _, prompt, gen = pending.pop(0)
            rid = eng.submit(prompt, gen)
            rids[rid] = (prompt, gen)
            arrive[rid] = time.perf_counter()
        eng.step()
        now = time.perf_counter()
        for req in eng.scheduler.running:
            if req.generated and req.rid not in first_tok:
                first_tok[req.rid] = now
        log = eng.scheduler.retired_log
        for rid_ in log[n_retired:]:
            first_tok.setdefault(rid_, now)
        n_retired = len(log)
        i += 1
    ttft = np.asarray([first_tok[r] - arrive[r] for r in rids]) * 1e3
    sched = eng.scheduler
    outs = {r: sched.finished[r].output for r in rids}
    return outs, dict(
        engine_steps=i,
        prefill_tokens_computed=eng.prefill_tokens_computed,
        prefix_hits=sched.prefix.hits if sched.prefix else 0,
        prefix_hit_tokens=sched.prefix.hit_tokens if sched.prefix else 0,
        ttft_p50_ms=float(np.percentile(ttft, 50)),
        ttft_p99_ms=float(np.percentile(ttft, 99)),
        peak_pages=eng.pool.alloc_peak,
        preemptions=sched.preemption_count,
    )


def _serve_decode_loop(cfg, params, page_size, max_batch, max_len,
                       n_pages, gen, k_steps, traced=False):
    """One full-batch cohort decoded with ``decode_steps=k_steps``.

    Every request has the same 4-token prompt length, the same ``gen``
    budget and no EOS, so the whole batch moves in lockstep and the
    dispatch count has a closed form: prefill samples token 1 on the
    host, then each engine step drives ONE jitted dispatch of K fused
    decode+sample iterations -- ``(gen - 1) / K`` dispatches total.

    With ``traced`` a TraceRecorder rides along and its
    DECODE_DISPATCH count is asserted against the engine counter AND
    its registry mirror (the caller asserts the closed form)."""
    rec = TraceRecorder() if traced else None
    eng = ContinuousEngine(cfg, params, n_pages=n_pages,
                           page_size=page_size, max_batch=max_batch,
                           max_len=max_len, decode_steps=k_steps,
                           trace=rec)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(max_batch)]
    warm = eng.submit(prompts[0], 2)       # warm prefill + decode jits
    eng.run()
    eng.scheduler.finished.pop(warm)
    eng.reset_counters()
    if rec is not None:
        rec.clear()
    # steady state: the decode dispatch+sync windows run under
    # jax.transfer_guard("disallow"), and the compile-count sentinel
    # must stay flat for EVERY jit (uniform prompt lengths -- even the
    # prefill buckets were warmed)
    eng.transfer_guard = True
    traces0 = dict(eng.trace_counts)

    rids = [eng.submit(p, gen) for p in prompts]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    retraces = {name: eng.trace_counts[name] - traces0[name]
                for name in traces0}
    assert not any(retraces.values()), \
        f"steady-state recompiles at decode_steps={k_steps}: {retraces}"
    toks = sum(len(eng.scheduler.finished[r].generated) for r in rids)
    outs = [np.asarray(eng.scheduler.finished[r].generated) for r in rids]
    if rec is not None:
        # one DECODE_DISPATCH event per jitted dispatch: the trace, the
        # engine counter and the registry must agree exactly
        assert rec.count("DECODE_DISPATCH") == eng.decode_dispatches \
            == eng.metrics.value("engine/decode_dispatches"), \
            (rec.count("DECODE_DISPATCH"), eng.decode_dispatches)
    return outs, dict(
        decode_steps=k_steps,
        tokens=toks, wall_s=dt, tokens_per_s=toks / dt,
        decode_dispatches=eng.decode_dispatches,
        dispatches_per_token=eng.decode_dispatches / (toks - len(rids)),
        page_table_uploads=eng.page_table_uploads,
        token_host_bytes=eng.token_host_bytes,
        logits_host_bytes=eng.logits_host_bytes,
        steady_state_retraces=sum(retraces.values()),
    )


def _serve_recurrent(cfg, params, max_batch, max_len, gen, k_steps):
    """A full-batch RWKV cohort decoded with ``decode_steps=k_steps``
    over the state-slab plane: zero KV pages ever, one posit8 slab per
    request, rewritten in place inside the fused loop.

    Asserted per engine step: the pool holds exactly one slab per live
    request and zero pages (constant-footprint admission), and the
    ``engine/state_bytes_per_step_model`` gauge equals both the pool's
    ``modeled_bytes_per_step`` and the closed form
    ``2 * state_slab_bytes * live`` (one slab read + one rewrite per
    request, independent of position -- the per-kind bytes/step
    model)."""
    eng = ContinuousEngine(cfg, params, n_pages=2, page_size=16,
                           max_batch=max_batch, max_len=max_len,
                           decode_steps=k_steps)
    sb = state_slab_bytes(cfg)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(max_batch)]
    warm = eng.submit(prompts[0], 2)       # warm prefill + decode jits
    eng.run()
    eng.scheduler.finished.pop(warm)
    eng.reset_counters()
    eng.transfer_guard = True
    traces0 = dict(eng.trace_counts)

    rids = [eng.submit(p, gen) for p in prompts]
    t0 = time.perf_counter()
    while eng.scheduler.has_work:
        eng.step()
        live = len(eng.scheduler.running)
        assert eng.pool.used_slabs == live, (eng.pool.used_slabs, live)
        assert eng.pool.used_pages == 0, eng.pool.used_pages
        served = list(eng.last_positions)
        gauge = eng.metrics.value("engine/state_bytes_per_step_model")
        assert gauge == eng.pool.modeled_bytes_per_step(served), gauge
        assert gauge == 2.0 * sb * len(served), (gauge, sb, len(served))
    dt = time.perf_counter() - t0
    retraces = {name: eng.trace_counts[name] - traces0[name]
                for name in traces0}
    assert not any(retraces.values()), \
        f"recurrent steady-state recompiles at K={k_steps}: {retraces}"
    # the footprint never grew past admission: one slab per request,
    # nothing preempted to make room (admission gates on free slabs)
    assert eng.pool.slab_alloc_peak == max_batch, eng.pool.slab_alloc_peak
    assert eng.pool.used_slabs == 0 and eng.pool.alloc_peak == 0
    assert eng.scheduler.preemption_count == 0
    want = (gen - 1) // k_steps
    assert eng.decode_dispatches == want, (k_steps, eng.decode_dispatches)
    assert eng.logits_host_bytes == 0
    assert eng.token_host_bytes == want * max_batch * k_steps * 4
    toks = sum(len(eng.scheduler.finished[r].generated) for r in rids)
    outs = [np.asarray(eng.scheduler.finished[r].generated) for r in rids]
    return outs, dict(
        decode_steps=k_steps,
        tokens=toks, wall_s=dt, tokens_per_s=toks / dt,
        decode_dispatches=eng.decode_dispatches,
        state_bytes_per_step_model=2.0 * sb * max_batch,
        slab_alloc_peak=eng.pool.slab_alloc_peak,
        kv_pages_allocated=eng.pool.alloc_peak,
        steady_state_retraces=sum(retraces.values()),
    )


def _serve_static(cfg, params, trace, max_len):
    """The static plan: wait for every arrival, left-pad one batch,
    decode until the longest request's budget."""
    eng = ServeEngine(cfg, params, max_len=max_len, quantized_kv=True)
    lens = [t[1].size for t in trace]
    s0 = max(lens)
    toks = np.zeros((len(trace), s0), np.int32)
    for i, (_, p, _) in enumerate(trace):
        toks[i, s0 - p.size:] = p
    steps = max(t[2] for t in trace)
    eng.generate(jnp.asarray(toks), steps=2,
                 lengths=np.asarray(lens))            # warm the jits
    t0 = time.perf_counter()
    eng.generate(jnp.asarray(toks), steps=steps, lengths=np.asarray(lens))
    dt = time.perf_counter() - t0
    useful = sum(t[2] for t in trace)                 # tokens anyone wanted
    return dict(wall_s=dt, steps=steps, batch=len(trace),
                useful_tokens=useful, tokens_per_s=useful / dt)


def run(smoke: bool = False) -> None:
    cfg = get_config("qwen2-0.5b").reduced()
    n_req = 8 if smoke else 16
    page_size = 16
    max_len = 48
    max_batch = 8
    n_pages = 6 * max_batch
    rng = np.random.default_rng(0)
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    trace = _trace(cfg, n_req, rng)
    results = {"config": {"arch": cfg.name, "n_req": n_req,
                          "page_size": page_size, "max_len": max_len,
                          "max_batch": max_batch, "n_pages": n_pages,
                          "backend": jax.default_backend()}}
    scenario_wall = {}
    t_sc = time.perf_counter()

    def lap(name):
        nonlocal t_sc
        scenario_wall[name] = round(time.perf_counter() - t_sc, 3)
        t_sc = time.perf_counter()

    eng, cont, positions_per_step = _serve_continuous(
        cfg, params, trace, n_pages, page_size, max_batch, max_len)
    static = _serve_static(cfg, params, trace, max_len)
    results["continuous"] = cont
    results["static"] = static
    emit("serve/continuous_tokens_per_s", 1e6 / max(cont["tokens_per_s"],
                                                    1e-9),
         f"tokens_per_s={cont['tokens_per_s']:.1f};"
         f"p50_ms={cont['latency_p50_ms']:.1f};"
         f"p99_ms={cont['latency_p99_ms']:.1f}")
    emit("serve/static_tokens_per_s", 1e6 / max(static["tokens_per_s"],
                                                1e-9),
         f"tokens_per_s={static['tokens_per_s']:.1f}")
    emit("serve/pool_utilization", 0.0,
         f"mean={cont['pool_util_mean']:.2f};"
         f"peak={cont['pool_util_peak']:.2f};"
         f"preemptions={cont['preemptions']}")
    lap("continuous_vs_static")

    # --- modeled KV bytes/step: live pages vs max_len plans
    paged_steps = [paged_kv_bytes_per_step(cfg, pos, page_size)
                   for pos in positions_per_step if pos]
    paged_mean = float(np.mean(paged_steps))
    # re-serve the SAME trace through an engine planned for 8x max_len
    # (8x wider page tables, same pool): the live positions -- and so
    # the paged bytes -- must not move by a single step
    _, _, positions_8x = _serve_continuous(
        cfg, params, trace, n_pages, page_size, max_batch, 8 * max_len)
    paged_8x = [paged_kv_bytes_per_step(cfg, pos, page_size)
                for pos in positions_8x if pos]
    assert paged_steps == paged_8x, \
        "paged KV bytes/step must not depend on max_len"
    # static plans at the trace's mean live batch: every row pays the
    # shared front position (length-aware) or the full buffer (bf16)
    bsz = static["batch"]
    front_pos = max(t[1].size for t in trace) + static["steps"] - 1
    static_q = decode_kv_bytes(cfg, bsz, max_len, front_pos,
                               quantized=True, blk=page_size)
    static_q_8x = decode_kv_bytes(cfg, bsz, 8 * max_len, front_pos,
                                  quantized=True, blk=page_size)
    static_bf16 = decode_kv_bytes(cfg, bsz, max_len, front_pos,
                                  quantized=False)
    static_bf16_8x = decode_kv_bytes(cfg, bsz, 8 * max_len, front_pos,
                                     quantized=False)
    results["kv_bytes_per_step"] = {
        "paged_mean": paged_mean,
        "paged_mean_8x_maxlen": float(np.mean(paged_8x)),
        "paged_peak": float(np.max(paged_steps)),
        "static_posit8_lenaware_front": static_q,
        "static_posit8_lenaware_front_8x_maxlen": static_q_8x,
        "static_bf16_full": static_bf16,
        "static_bf16_full_8x_maxlen": static_bf16_8x,
        "paged_vs_static_bf16_gain": static_bf16 / paged_mean,
    }
    emit("serve/kv_bytes_per_step", 0.0,
         f"paged={paged_mean:.0f};static_posit8={static_q:.0f};"
         f"static_bf16={static_bf16:.0f};"
         f"gain={static_bf16 / paged_mean:.2f}x")
    assert paged_mean <= static_q, \
        "live-page accounting must beat the shared-front static plan"
    assert static_bf16_8x == 8 * static_bf16, \
        "the bf16 plan pays max_len (that is the waste being removed)"
    lap("kv_bytes_per_step")

    # --- chunked prefill: long-prompt arrival, p99 step latency
    lp_max_len = 112                     # default_kv_block(112) == 16 ==
    #                                      page: the static-parity condition
    rids_m, outs_m, p99_mono = _serve_long_prompt(
        cfg, params, page_size, lp_max_len, chunk=None)
    rids_c, outs_c, p99_chunk = _serve_long_prompt(
        cfg, params, page_size, lp_max_len, chunk=page_size)
    static_lp = ServeEngine(cfg, params, max_len=lp_max_len,
                            quantized_kv=True)
    for rids, outs in ((rids_m, outs_m), (rids_c, outs_c)):
        for rid, (p, g) in rids.items():
            want = static_lp.generate(jnp.asarray(p)[None], steps=g)[0]
            assert np.array_equal(outs[rid], want), \
                "chunked/monolithic prefill must stay token-for-token " \
                "identical to static per-request generation"
    assert p99_chunk < p99_mono, (
        "chunked prefill must bound p99 step latency below the "
        f"monolithic long-prompt stall ({p99_chunk:.2f} vs "
        f"{p99_mono:.2f} ms)")
    results["chunked_prefill"] = {
        "long_prompt_tokens": 5 * page_size,
        "prefill_chunk_tokens": page_size,
        "p99_step_ms_monolithic": p99_mono,
        "p99_step_ms_chunked": p99_chunk,
        "p99_stall_reduction": p99_mono / max(p99_chunk, 1e-9),
        "static_parity": True,
    }
    emit("serve/chunked_prefill_p99_step", p99_chunk * 1e3,
         f"chunked_p99_ms={p99_chunk:.2f};mono_p99_ms={p99_mono:.2f};"
         f"stall_reduction={p99_mono / max(p99_chunk, 1e-9):.2f}x;"
         f"static_parity=1")
    lap("chunked_prefill")

    # --- disaggregated prefill/decode: the same burst shape, but the
    # decode worker's critical path (dispatch + token sync) never
    # contains a prefill chunk -- the prefill worker runs inside the
    # async overlap window while the device scans the decode loop
    eng_i, rids_i, outs_i, p99_inter, _ = _serve_disagg_burst(
        cfg, params, page_size, lp_max_len, disagg=False)
    eng_d, rids_d, outs_d, p99_disagg, rec_d = _serve_disagg_burst(
        cfg, params, page_size, lp_max_len, disagg=True)
    static_dg = ServeEngine(cfg, params, max_len=lp_max_len,
                            quantized_kv=True)
    for rids, outs in ((rids_i, outs_i), (rids_d, outs_d)):
        for rid, (p, g) in rids.items():
            want = static_dg.generate(jnp.asarray(p)[None], steps=g)[0]
            assert np.array_equal(outs[rid], want), \
                "disaggregated serving must stay token-for-token " \
                "identical to static per-request generation"
    assert p99_disagg <= p99_inter, (
        "the disaggregated decode worker's p99 step latency must not "
        "exceed the interleaved engine's (decode isolation): "
        f"{p99_disagg:.2f} vs {p99_inter:.2f} ms")
    # channel traffic is EXACTLY the posit8 page model: codes + group
    # scales, nothing re-inflated to bf16
    assert eng_d.handoff_bytes == eng_d.handoff_pages * \
        page_handoff_bytes(cfg, page_size), eng_d.handoff_bytes
    # 4 drives x 5 requests, every one crosses the channel exactly once
    assert eng_d.handoffs == 4 * len(rids_d), eng_d.handoffs
    assert eng_d.decode_bounces == 0, eng_d.decode_bounces
    # the trace mirrors the channel counters EXACTLY across all 4
    # drives (no reset between drives; the recorder's per-kind count /
    # arg-sum accumulators are eviction-proof) -- the observability
    # acceptance tie-out: HANDOFF events == handoffs, and their summed
    # pages/bytes args == the posit8 page model
    assert rec_d.count("HANDOFF") == eng_d.handoffs, \
        (rec_d.count("HANDOFF"), eng_d.handoffs)
    assert rec_d.arg_sum("HANDOFF", "pages") == eng_d.handoff_pages, \
        rec_d.arg_sum("HANDOFF", "pages")
    assert rec_d.arg_sum("HANDOFF", "bytes") == eng_d.handoff_bytes, \
        rec_d.arg_sum("HANDOFF", "bytes")
    assert eng_d.metrics.value("channel/handoffs") == eng_d.handoffs
    # export the disagg burst's trace and schema-validate it: the
    # artifact CI checks is Perfetto-loadable by construction
    os.makedirs(os.path.dirname(TRACE_JSON), exist_ok=True)
    rec_d.write_chrome_trace(TRACE_JSON)
    with open(TRACE_JSON) as f:
        tstats = validate_chrome_trace(json.load(f))
    results["disagg"] = {
        "trace_events": tstats,
        "n_req": len(rids_d),
        "long_prompt_tokens": 4 * page_size,
        "p99_decode_step_ms_interleaved": p99_inter,
        "p99_decode_step_ms_disagg": p99_disagg,
        "decode_stall_reduction": p99_inter / max(p99_disagg, 1e-9),
        "handoffs": eng_d.handoffs,
        "handoff_pages": eng_d.handoff_pages,
        "handoff_bytes": eng_d.handoff_bytes,
        "handoff_bytes_per_page": page_handoff_bytes(cfg, page_size),
        "decode_bounces": eng_d.decode_bounces,
        "static_parity": True,
    }
    emit("serve/disagg_decode_p99_step", p99_disagg * 1e3,
         f"disagg_p99_ms={p99_disagg:.2f};"
         f"interleaved_p99_ms={p99_inter:.2f};"
         f"handoffs={eng_d.handoffs};"
         f"handoff_bytes={eng_d.handoff_bytes};"
         f"bounces={eng_d.decode_bounces};static_parity=1")
    emit("serve/trace_artifact", 0.0,
         f"events={tstats['total']};spans={tstats['spans']};"
         f"instants={tstats['instants']};"
         f"path={os.path.normpath(TRACE_JSON)}")
    lap("disagg")

    # --- prefix caching: shared-preamble arrivals, cache on vs off
    pre_pages = 2
    pre_trace = _preamble_trace(cfg, np.random.default_rng(5), 6,
                                pre_pages * page_size,
                                arrival_gap=pre_pages + 1)
    outs_off, off = _serve_shared_preamble(
        cfg, params, pre_trace, 32, page_size, 4, max_len,
        prefix_cache=False)
    outs_on, on = _serve_shared_preamble(
        cfg, params, pre_trace, 32, page_size, 4, max_len,
        prefix_cache=True)
    for rid in outs_off:
        assert np.array_equal(outs_on[rid], outs_off[rid]), (
            "prefix-cache hits must stay token-for-token identical to "
            f"the cache-off engine (rid {rid}): the shared pages hold "
            "bitwise the codes a cold prefill writes")
    # hits/hit_tokens count per ADMISSION; the pool is sized so nothing
    # is preempted and the counters map 1:1 onto requests -- keep that
    # explicit or the arithmetic below silently changes meaning
    assert on["preemptions"] == 0 and off["preemptions"] == 0, (on, off)
    assert on["prefix_hits"] == len(pre_trace) - 1, on
    # every request AFTER the first sharer must re-prefill at most half
    # its prompt (it skips the matched preamble pages)
    later_prompt = sum(t[1].size for t in pre_trace[1:])
    later_computed = later_prompt - on["prefix_hit_tokens"]
    assert later_prompt >= 2 * later_computed, (
        "prefix caching must at least halve the prefill tokens of "
        f"requests after the first sharer ({later_computed} computed "
        f"of {later_prompt})")
    results["prefix_cache"] = {
        "preamble_tokens": pre_pages * page_size,
        "n_req": len(pre_trace),
        "prefill_tokens_computed_off": off["prefill_tokens_computed"],
        "prefill_tokens_computed_on": on["prefill_tokens_computed"],
        "prefill_tokens_saved": on["prefix_hit_tokens"],
        "later_req_prefill_reduction":
            later_prompt / max(later_computed, 1),
        "prefix_hits": on["prefix_hits"],
        "ttft_p50_ms_off": off["ttft_p50_ms"],
        "ttft_p50_ms_on": on["ttft_p50_ms"],
        "ttft_p99_ms_off": off["ttft_p99_ms"],
        "ttft_p99_ms_on": on["ttft_p99_ms"],
        "parity": True,
    }
    emit("serve/prefix_cache_ttft_p50", on["ttft_p50_ms"] * 1e3,
         f"on_p50_ms={on['ttft_p50_ms']:.2f};"
         f"off_p50_ms={off['ttft_p50_ms']:.2f};"
         f"on_p99_ms={on['ttft_p99_ms']:.2f};"
         f"off_p99_ms={off['ttft_p99_ms']:.2f}")
    emit("serve/prefix_cache_prefill_tokens", 0.0,
         f"computed_on={on['prefill_tokens_computed']};"
         f"computed_off={off['prefill_tokens_computed']};"
         f"saved={on['prefix_hit_tokens']};"
         f"later_req_reduction="
         f"{later_prompt / max(later_computed, 1):.1f}x;parity=1")
    lap("prefix_cache")

    # --- device-resident decode loop: K fused decode+sample steps per
    # dispatch; the host syncs one (B, K) int32 buffer and ZERO logits
    gen = 17                       # 1 prefill-sampled + 16 decoded:
    #                                16 is divisible by every K below
    dl_results = {}
    base_out = None
    for k_steps in (1, 4, 8):
        # K=1 runs UNTRACED while K=4/8 run traced, so the cross-K
        # token-equality assert below doubles as the traced-vs-
        # untraced temperature-0 parity check (tracing never touches
        # device math)
        outs, stats = _serve_decode_loop(
            cfg, params, page_size, max_batch, max_len, n_pages,
            gen, k_steps, traced=k_steps != 1)
        # closed-form dispatch model: lockstep cohort, (gen-1)/K
        # dispatches, one (max_batch, K) int32 sync each, no logits
        # (with tracing on, _serve_decode_loop already tied the trace's
        # DECODE_DISPATCH count to this same counter)
        want = (gen - 1) // k_steps
        assert stats["decode_dispatches"] == want, (k_steps, stats)
        assert stats["logits_host_bytes"] == 0, stats
        assert stats["token_host_bytes"] == want * max_batch * \
            k_steps * 4, (k_steps, stats)
        # temperature-0 parity: every K must emit the same tokens
        if base_out is None:
            base_out = outs
        for a, b_ in zip(base_out, outs):
            assert np.array_equal(a, b_), \
                f"decode_steps={k_steps} changed temperature-0 output"
        dl_results[f"K{k_steps}"] = stats
        emit(f"serve/decode_loop_K{k_steps}",
             1e6 / max(stats["tokens_per_s"], 1e-9),
             f"tokens_per_s={stats['tokens_per_s']:.1f};"
             f"dispatches={stats['decode_dispatches']};"
             f"dispatches_per_token="
             f"{stats['dispatches_per_token']:.3f};"
             f"pt_uploads={stats['page_table_uploads']};"
             f"token_bytes={stats['token_host_bytes']};"
             f"logits_bytes=0")
    # what the pre-fusion loop moved: one (B, vocab) f32 logits pull
    # per decoded token, now zero
    dl_results["logits_bytes_removed_per_run"] = \
        (gen - 1) * max_batch * cfg.vocab * 4
    results["decode_loop"] = dl_results
    lap("decode_loop")

    # --- paged STATE: an RWKV cohort served off the slab plane (zero
    # KV pages; constant per-request footprint; per-kind bytes model)
    r_cfg = get_config("rwkv6-1.6b").reduced()
    r_params = zoo.init_model(jax.random.PRNGKey(1), r_cfg)
    r_batch = 4
    rec_results = {"state_slab_bytes": state_slab_bytes(r_cfg)}
    rec_base = None
    for k_steps in (1, 4):
        outs, stats = _serve_recurrent(r_cfg, r_params, r_batch, max_len,
                                       gen, k_steps)
        if rec_base is None:
            rec_base = outs
        for a, b_ in zip(rec_base, outs):
            assert np.array_equal(a, b_), \
                f"recurrent decode_steps={k_steps} changed temp-0 output"
        rec_results[f"K{k_steps}"] = stats
        emit(f"serve/recurrent_K{k_steps}",
             1e6 / max(stats["tokens_per_s"], 1e-9),
             f"tokens_per_s={stats['tokens_per_s']:.1f};"
             f"dispatches={stats['decode_dispatches']};"
             f"state_bytes_per_step="
             f"{stats['state_bytes_per_step_model']:.0f};"
             f"slab_peak={stats['slab_alloc_peak']};kv_pages=0")
    results["recurrent"] = rec_results
    lap("recurrent")

    # --- slot waste: reserved slots vs live tokens
    reserved = bsz * max_len
    live_mean = float(np.mean([sum(p + 1 for p in pos)
                               for pos in positions_per_step if pos]))
    results["slot_waste"] = {
        "static_reserved_slots": reserved,
        "paged_live_tokens_mean": live_mean,
        "reserved_over_live": reserved / max(live_mean, 1.0),
    }
    emit("serve/slot_waste", 0.0,
         f"static_reserved={reserved};live_mean={live_mean:.0f};"
         f"ratio={reserved / max(live_mean, 1.0):.1f}x")
    lap("slot_waste")
    results["scenario_wall_s"] = scenario_wall

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(OUT_JSON)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (the CI invocation)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
