"""Shared benchmark utilities: timing + CSV emission.

The timing/percentile helpers live in ``repro.obs.stats`` (one shared
implementation for benchmarks and the serving-plane telemetry);
``time_call`` is re-exported here so existing bench imports keep
working unchanged.
"""

from __future__ import annotations

from repro.obs.stats import pctl_ms, percentiles, time_call  # noqa: F401

__all__ = ["time_call", "pctl_ms", "percentiles", "emit"]


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
