"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
