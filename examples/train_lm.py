"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production feature set -- QAT (paper mixed precision), posit8
gradient compression with error feedback, 8-bit Adam, microbatch
accumulation, and async checkpoint/restart.

~100M params: qwen2-0.5b geometry at 8 layers / d=512 (vocab dominates).
CPU pace is ~20-30 s/step (the 152k-vocab readout dominates), so 200 steps
is a multi-hour CPU run; pass --steps 30 --seq 64 for a smoke run.  The
loop checkpoints every 50 steps and resumes exactly, so long runs survive
interruption (validated to step 50+ in-session; loss decrease + resume are
also asserted at smaller scale by tests/test_train.py).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import TokenStream
from repro.train.loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        name="qwen2-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
        head_dim=64, d_ff=2048, vocab=151936, remat="none", seq_chunk=128)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")

    run = RunConfig(
        arch=cfg.name, steps=args.steps, lr=1e-3, warmup_steps=20,
        microbatch=2, qat=True, precision_policy="mixed",
        grad_compression="posit8", opt_state_dtype="posit8",
        checkpoint_every=50, checkpoint_dir=args.ckpt)
    data = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)
    state, hist = train_loop(cfg, run, data, log_every=10)
    assert hist["loss"][-1] < hist["loss"][0], "training must reduce loss"
    print(f"done: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"at step {int(state.step)}")


if __name__ == "__main__":
    main()
