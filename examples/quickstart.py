"""Quickstart: the XR-NPE pipeline in 60 lines.

1. Build a model (qwen2-0.5b reduced), take one calibration gradient.
2. Derive the layer-adaptive precision policy (paper eq. 1-2).
3. QAT-train a few steps with fake-quantized weights (STE).
4. Pack the weights for serving (real low-bit storage) and generate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.sensitivity import assign_layer_adaptive, sensitivity_report
from repro.data import TokenStream
from repro.models import zoo
from repro.serve.engine import ServeEngine
from repro.train.loop import build_train_step, init_state

cfg = get_config("qwen2-0.5b").reduced()
run = RunConfig(arch="qwen2-0.5b", steps=30, lr=3e-3, warmup_steps=5,
                qat=True, precision_policy="adaptive", checkpoint_every=0)
data = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=8)

# --- 1. calibration gradient ------------------------------------------------
state = init_state(jax.random.PRNGKey(0), cfg, run)
batch = data.next_batch()
grads = jax.grad(lambda p: zoo.loss_fn(p, batch, cfg)[0])(state.params)

# --- 2. layer-adaptive policy (eq. 1-2) --------------------------------------
policy = assign_layer_adaptive(state.params, grads, target_avg_bits=6.0)
print(sensitivity_report(state.params, grads).split("\n")[0])
print(f"policy: avg {policy.average_bits(state.params):.2f} bits/weight, "
      f"packed model {policy.model_bytes(state.params)/1e6:.2f} MB "
      f"(fp32 {sum(x.size*4 for x in jax.tree.leaves(state.params))/1e6:.2f} MB)")

# --- 3. QAT ------------------------------------------------------------------
step = build_train_step(cfg, run, policy)
for i in range(run.steps):
    state, metrics = step(state, data.next_batch())
    if (i + 1) % 10 == 0:
        print(f"QAT step {i+1}: loss {float(metrics['loss']):.4f}")

# --- 4. packed serving --------------------------------------------------------
eng = ServeEngine(cfg, state.params, max_len=96, policy=policy)
prompt = data.next_batch()["tokens"][:2, :8]
out = eng.generate(prompt, steps=8)
print("generated:", out[:, 8:])
print("OK")
