"""The paper's headline workload: UL-VIO with layer-adaptive mixed
precision, end to end.

1. Train the VIO model (visual + IMU fusion) on synthetic KITTI-like
   sequences to a useful translation/rotation RMSE.
2. Score layers with the eq.1-2 sensitivity metric; assign HFP4/Posit
   formats under a 6-bit average budget.
3. Compare FP32 vs FP4 vs mixed-precision RMSE (the paper's Fig. 6) and
   model bytes (13.5 -> 2.42 MB story).
4. Serve a batch of "frames" through the quantized model.

Run:  PYTHONPATH=src python examples/vio_serve.py [--continuous]

``--continuous`` additionally demos the XR serving story end-to-end:
concurrent perception-narration streams of very different lengths are
submitted to the paged-KV ``ContinuousEngine`` as they "arrive" --
admission, batched paged decode and retirement all run while the VIO
frames keep being served, which is how an XR device multiplexes VIO /
gaze / classification traffic without paying worst-case KV memory per
stream.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.core.qat import quantize_tree
from repro.core.sensitivity import assign_layer_adaptive
from repro.data.vio_data import VIOStream
from repro.models import perception as P

ARGS = argparse.ArgumentParser()
ARGS.add_argument("--continuous", action="store_true",
                  help="also serve staggered LM streams through the "
                       "paged-KV ContinuousEngine")
ARGS.add_argument("--decode-steps", type=int, default=2,
                  help="decode iterations per jitted dispatch of the "
                       "--continuous demo: one host round trip drives K "
                       "on-device decode+sample steps (temperature-0 "
                       "tokens are identical for every K)")
ARGS.add_argument("--disagg", action="store_true",
                  help="serve the --continuous stream mix through the "
                       "disaggregated prefill/decode engine instead: "
                       "prefill worker + uninterrupted decode worker "
                       "joined by a posit8 page-handoff channel "
                       "(implies --continuous)")
ARGS = ARGS.parse_args()
ARGS.continuous = ARGS.continuous or ARGS.disagg

stream = VIOStream(batch=64)
params = P.vio_init(jax.random.PRNGKey(0))


@jax.jit
def step(p, batch):
    (l, m), g = jax.value_and_grad(P.vio_loss, has_aux=True)(p, batch)
    return jax.tree.map(lambda a, b: a - 1e-3 * b, p, g), m


print("training UL-VIO on synthetic KITTI-like sequences...")
for i in range(400):
    b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    params, m = step(params, b)
    if (i + 1) % 100 == 0:
        print(f"  step {i+1}: t-RMSE {float(m['t_rmse']):.4f} m, "
              f"r-RMSE {float(m['r_rmse']):.4f} rad")

test = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
grads = jax.grad(lambda p: P.vio_loss(p, test)[0])(params)
policy = assign_layer_adaptive(params, grads, target_avg_bits=6.0)

rows = [("fp32", PrecisionPolicy.uniform("fp32")),
        ("posit8", PrecisionPolicy.uniform("posit8_0")),
        ("fp4", PrecisionPolicy.uniform("fp4")),
        ("mxp(eq.1-2)", policy)]
print(f"\n{'policy':>12s} {'t-RMSE':>8s} {'r-RMSE':>8s} {'MB':>6s}")
base = None
for name, pol in rows:
    q = quantize_tree(params, pol)
    _, m = P.vio_loss(q, test)
    mb = pol.model_bytes(params) / 1e6
    t, r = float(m["t_rmse"]), float(m["r_rmse"])
    if base is None:
        base = (t, r)
    print(f"{name:>12s} {t:8.4f} {r:8.4f} {mb:6.2f}"
          f"   (dt {100*(t-base[0]):+.2f}pp, dr {100*(r-base[1]):+.2f}pp)")

# serve a batch through the mixed-precision model
q = quantize_tree(params, policy)
pose = P.vio_apply(q, test)
print(f"\nserved {pose.shape[0]} frame-pairs; "
      f"first pose estimate: {np.asarray(pose[0])}")

if ARGS.continuous:
    # concurrent perception streams: staggered arrivals, ragged lengths,
    # one paged-KV pool -- the serving plane the static batch can't grow
    # into (see serve/__init__ for the page-table layout).
    from repro.configs import get_config
    from repro.models import zoo
    from repro.obs import TraceRecorder
    from repro.serve import ContinuousEngine

    # request-lifecycle tracing: the engine stamps SUBMIT/ADMIT/.../
    # RETIRE per stream, from which per-stream SLOs (TTFT, TPOT, queue
    # wait) are derived below -- the numbers XR latency classes are
    # scheduled on (docs/observability.md)
    recorder = TraceRecorder()
    cfg = get_config("qwen2-0.5b").reduced()
    lm = zoo.init_model(jax.random.PRNGKey(7), cfg)
    # chunked paged prefill: one engine step pays at most 16 prefill
    # tokens, so a long narration prompt never stalls the VIO-adjacent
    # decode streams for a full prefill (p99 stays chunk-bounded).
    # prefix_cache: every stream opens with the SAME scene preamble
    # (the XR pattern -- one system/scene prompt ahead of every VIO /
    # gaze / narration query), so only the first sharer pays its
    # prefill; later streams attach the cached pages copy-on-write.
    # decode_steps: each engine step drives K decode+sample iterations
    # in ONE jitted dispatch (device-resident sampling; streams that
    # finish mid-scan park on page 0) -- the XR frame loop polls the
    # engine K tokens at a time instead of once per token.
    if ARGS.disagg:
        # disaggregated: the decode worker's K-step loop never waits on
        # a prefill chunk -- the long narration prompt prefills on the
        # OTHER worker while VIO-adjacent streams keep decoding, and
        # only its compressed posit8 pages cross the handoff channel
        from repro.serve import DisaggEngine
        eng = DisaggEngine(cfg, lm, prefill_pages=32, decode_pages=32,
                           page_size=16, max_batch=4, max_len=64,
                           policy=PrecisionPolicy.uniform("posit8_0"),
                           prefill_chunk_tokens=16, prefix_cache=True,
                           decode_steps=ARGS.decode_steps,
                           trace=recorder)
    else:
        eng = ContinuousEngine(cfg, lm, n_pages=32, page_size=16,
                               max_batch=4, max_len=64,
                               policy=PrecisionPolicy.uniform("posit8_0"),
                               prefill_chunk_tokens=16, prefix_cache=True,
                               decode_steps=ARGS.decode_steps,
                               trace=recorder)
    rng = np.random.default_rng(0)
    scene = rng.integers(0, cfg.vocab, (16,))   # shared scene preamble
    arrivals = [(s, int(rng.integers(3, 12)), int(rng.integers(4, 16)))
                for s in (0, 0, 1, 2, 2, 4)]   # (arrive_step, plen, gen)
    arrivals.append((3, 24, 6))   # a long prompt lands mid-decode:
    #                               chunked prefill absorbs it 16 at a time
    print("\ncontinuous XR streams (arrive@step, tail, gen):", arrivals)
    pending = sorted(arrivals, key=lambda a: a[0])
    sched = eng.prefill.scheduler if ARGS.disagg else eng.scheduler
    step = 0
    while pending or (eng.has_work if ARGS.disagg else sched.has_work):
        while pending and pending[0][0] <= step:
            _, plen, gen = pending.pop(0)
            prompt = np.concatenate(
                [scene, rng.integers(0, cfg.vocab, (plen,))])
            eng.submit(prompt, gen)
        eng.step()
        step += 1
    done = eng.finished if ARGS.disagg else sched.finished
    px = sched.prefix
    if ARGS.disagg:
        print(f"served {len(done)} streams in {step} engine steps; "
              f"pool peaks prefill "
              f"{eng.prefill.pool.alloc_peak}/{eng.prefill.pool.n_pages} "
              f"decode {eng.decode.pool.alloc_peak}/"
              f"{eng.decode.pool.n_pages} pages; "
              f"prefix cache {px.hits} hits "
              f"({px.hit_tokens} prefill tokens skipped)")
        print(f"handoff: {eng.handoffs} handoffs, {eng.handoff_pages} "
              f"posit8 pages, {eng.handoff_bytes} bytes over the "
              f"channel, {eng.decode_bounces} decode bounces")
    else:
        print(f"served {len(done)} streams in {step} engine steps; "
              f"peak pool use {eng.pool.alloc_peak}/{eng.pool.n_pages} "
              f"pages, preemptions {sched.preemption_count}; "
              f"prefix cache {px.hits} hits "
              f"({px.hit_tokens} prefill tokens skipped)")
    print(f"decode loop: K={eng.decode_steps}, "
          f"{eng.decode_dispatches} dispatches, "
          f"{eng.page_table_uploads} page-table uploads, "
          f"{eng.logits_host_bytes} logits bytes to host")
    # per-stream SLOs from the lifecycle trace: time-to-first-token,
    # inter-token latency and queue wait per XR stream, plus aggregate
    # percentiles -- what a latency-class scheduler would act on
    print("stream SLOs (ms):")
    for name, s in recorder.slo_summary().items():
        print(f"  {name:>17}: p50 {s['p50']:8.2f}  p95 {s['p95']:8.2f}  "
              f"p99 {s['p99']:8.2f}  (n={s['n']})")
    util = eng.metrics.value(
        "decode/pool/utilization" if ARGS.disagg else "pool/utilization")
    print(f"pool utilization at drain: {util:.2f}; "
          f"{recorder.count('PREFILL_CHUNK')} prefill chunks traced "
          f"across {len(recorder)} ring events")
print("OK")
