#!/usr/bin/env python
"""Schema-validate a Chrome-trace JSON file (Perfetto-loadable check).

Thin CLI over ``repro.obs.trace.validate_chrome_trace``: verifies the
trace-event envelope (``traceEvents`` list, known phase codes, numeric
non-negative timestamps/durations, integer pid/tid) that Perfetto and
chrome://tracing require, and prints the event census.

  PYTHONPATH=src python tools/validate_trace.py artifacts/serve_trace.json
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import validate_chrome_trace


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON file to validate")
    args = ap.parse_args()
    with open(args.trace) as f:
        obj = json.load(f)
    try:
        stats = validate_chrome_trace(obj)
    except ValueError as e:
        print(f"{args.trace}: INVALID: {e}", file=sys.stderr)
        return 1
    print(f"{args.trace}: OK -- {stats['total']} events "
          f"({stats['spans']} spans, {stats['instants']} instants, "
          f"{stats['metadata']} metadata)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
