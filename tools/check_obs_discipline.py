#!/usr/bin/env python
"""Static observability-discipline check for the serving layer.

The serving-plane counters (``ContinuousEngine.decode_dispatches``,
``PageHandoffChannel.handoffs``, ...) read like plain attributes but
are registry-backed: ``repro.obs.metrics.bind_counters`` installs data
descriptors for every name in a class's ``_COUNTERS`` tuple, so
``self.x += 1`` routes through a ``MetricRegistry`` Counter.  That
contract only holds for DECLARED names -- an increment of an
undeclared attribute silently re-creates the pre-PR-8 world of bare
counters the registry never sees.

This check walks ``src/repro/serve/*.py`` ASTs and fails when:

  1. a class declares ``_COUNTERS`` but never calls ``bind_counters``
     (its "counters" would be plain ints, invisible to the registry);
  2. an augmented assignment on ``self.<name>`` (or a chain rooted at
     ``self``, e.g. ``self.prefix.misses``) targets a name that is in
     no ``_COUNTERS`` tuple anywhere in the serving layer -- i.e. a
     bare counter mutated outside the registry API.

Allowlisted: ``epoch`` (the scheduler's page-table cache-invalidation
token -- versioning state, not a metric) and ``_``-prefixed private
state (``self._next_rid`` etc.).

  python tools/check_obs_discipline.py        # exit 1 on violation
"""

from __future__ import annotations

import ast
import os
import sys

SERVE_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "src", "repro", "serve")
ALLOW = {"epoch"}


def _counter_decls(tree: ast.Module):
    """Yield (class_name, names, binds) per class: its ``_COUNTERS``
    tuple entries (empty if undeclared) and whether any method calls
    ``bind_counters``."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        names, binds = [], False
        for stmt in node.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id == "_COUNTERS" \
                            and isinstance(stmt.value, ast.Tuple):
                        names = [e.value for e in stmt.value.elts
                                 if isinstance(e, ast.Constant)]
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                fn = sub.func
                callee = fn.id if isinstance(fn, ast.Name) else \
                    fn.attr if isinstance(fn, ast.Attribute) else None
                if callee == "bind_counters":
                    binds = True
        yield node.name, names, binds


def _rooted_at_self(node: ast.expr) -> bool:
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id == "self"


def check() -> int:
    trees = {}
    declared: set = set()
    failures = []
    for fn in sorted(os.listdir(SERVE_DIR)):
        if not fn.endswith(".py"):
            continue
        path = os.path.normpath(os.path.join(SERVE_DIR, fn))
        with open(path) as f:
            trees[path] = ast.parse(f.read(), filename=path)
    for path, tree in trees.items():
        for cls, names, binds in _counter_decls(tree):
            declared.update(names)
            if names and not binds:
                failures.append(
                    f"{path}: class {cls} declares _COUNTERS but never "
                    f"calls bind_counters -- its counters are bare ints "
                    f"the MetricRegistry cannot see")
    for path, tree in trees.items():
        for node in ast.walk(tree):
            if not (isinstance(node, ast.AugAssign)
                    and isinstance(node.target, ast.Attribute)):
                continue
            attr = node.target.attr
            if attr.startswith("_") or attr in ALLOW or attr in declared:
                continue
            if not _rooted_at_self(node.target.value):
                continue            # request/local object state, not a counter
            failures.append(
                f"{path}:{node.lineno}: 'self...{attr} (op)=' mutates a "
                f"bare attribute declared in no _COUNTERS tuple; declare "
                f"it (registry-backed via bind_counters) or rename it "
                f"_{attr} if it is private state")
    for f in failures:
        print(f"obs-discipline: {f}", file=sys.stderr)
    if not failures:
        n = sum(1 for _ in trees)
        print(f"obs-discipline: OK ({n} serve modules, "
              f"{len(declared)} registry-backed counter names)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(check())
