#!/usr/bin/env python
"""Static observability-discipline check for the serving layer (shim).

The check itself now lives in the analysis framework as the registered
rule ``obs-counter-discipline`` (``tools/analysis/rules/obs_counters.py``
-- same two failures: a ``_COUNTERS`` class that never calls
``bind_counters``, and a ``self.<attr> (op)=`` on a name no
``_COUNTERS`` tuple declares).  This entry point survives so the
existing CI step and local habits keep working:

  python tools/check_obs_discipline.py        # exit 1 on violation

which is equivalent to:

  python -m tools.analysis --rules obs-counter-discipline
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir))

from tools.analysis import run_paths  # noqa: E402


def check() -> int:
    findings = run_paths(paths=[], rules=["obs-counter-discipline"])
    for f in findings:
        print(f"obs-discipline: {f.path}:{f.line}: {f.message}",
              file=sys.stderr)
    if not findings:
        print("obs-discipline: OK (rule obs-counter-discipline via "
              "tools.analysis)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(check())
