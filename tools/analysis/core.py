"""Core of the repro static-analysis framework (``tools/analysis``).

The serving plane's headline properties -- temp-0 parity, a decode
critical path with zero host syncs, ``(gen-1)/K`` dispatches with zero
steady-state recompiles -- are pinned dynamically by tests and bench
asserts.  The *disciplines* that make them hold (no implicit
device->host transfer in ``step()``, never read a donated buffer after
the jitted call, every Pallas kernel ships a ref oracle + XLA
fallback, scheduler decisions never consult wall clocks or unsorted
sets) used to be unwritten conventions.  This package turns each one
into a registered AST rule so a violating diff fails in CI instead of
shifting a bench percentile nobody attributes.

Layout:

  * ``Finding``      -- one (rule, path, line, message) violation
  * ``FileContext``  -- parsed source + ``# repro: allow(rule)`` map
  * ``RepoContext``  -- lazy cross-file access for repo-level rules
  * ``Rule``         -- a named check: per-file, repo-level, or both
  * ``register``     -- the rule registry (populated by
                        ``tools.analysis.rules`` on first use)
  * ``run_paths`` / ``run_source`` -- the two entry points (CLI /
                        tests)

Suppression: a ``# repro: allow(<rule>[, <rule>...])`` comment on the
finding's line, or on the line directly above it, silences that rule
there.  ``allow(*)`` silences every rule.  Suppressions are expected
to carry a justification in the surrounding comment (docs/analysis.md).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

REPO_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 os.pardir, os.pardir))

#: scanned when the CLI is given no paths; tests/ and tools/ stay out
#: (rule fixtures and the checkers themselves would trip the rules)
DEFAULT_PATHS = ("src", "benchmarks", "examples")

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str      # repo-relative, forward slashes
    line: int
    message: str

    def key(self):
        """Baseline identity: line numbers drift under unrelated edits,
        so a baseline matches on (rule, path, message) only."""
        return (self.rule, self.path, self.message)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """One parsed source file: AST, raw lines and the allow-comment map."""

    def __init__(self, relpath: str, source: str):
        self.path = relpath.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=self.path)
        self.allow: Dict[int, Set[str]] = {}
        for lineno, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if m:
                self.allow[lineno] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}

    def allowed(self, rule: str, line: int) -> bool:
        """True when ``# repro: allow(<rule>)`` sits on the finding's
        line or the line directly above it."""
        for ln in (line, line - 1):
            rules = self.allow.get(ln)
            if rules and (rule in rules or "*" in rules):
                return True
        return False


class RepoContext:
    """Lazy, cached access to files across the repo -- what repo-level
    rules (kernel-oracle coverage, obs-counter discipline) use to read
    modules outside the scanned path set."""

    def __init__(self, root: str = REPO_ROOT):
        self.root = root
        self._cache: Dict[str, Optional[FileContext]] = {}

    def get(self, relpath: str) -> Optional[FileContext]:
        key = relpath.replace(os.sep, "/")
        if key not in self._cache:
            full = os.path.join(self.root, *key.split("/"))
            if not os.path.isfile(full):
                self._cache[key] = None
            else:
                with open(full, encoding="utf-8") as f:
                    self._cache[key] = FileContext(key, f.read())
        return self._cache[key]

    def listdir(self, relpath: str) -> List[str]:
        full = os.path.join(self.root, *relpath.split("/"))
        if not os.path.isdir(full):
            return []
        return sorted(os.listdir(full))


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered check.  ``check_file`` runs once per scanned file;
    ``check_repo`` runs once per invocation against the whole repo
    (cross-file invariants).  A rule may define either or both."""

    name: str
    summary: str
    check_file: Optional[Callable[[FileContext], Iterable[Finding]]] = None
    check_repo: Optional[Callable[[RepoContext], Iterable[Finding]]] = None


_REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.name in _REGISTRY:
        raise ValueError(f"duplicate rule name: {rule.name}")
    _REGISTRY[rule.name] = rule
    return rule


def all_rules() -> List[Rule]:
    """Every registered rule, name-sorted.  Importing the rules package
    is what populates the registry (each rule module self-registers)."""
    from . import rules  # noqa: F401  (import for side effect)
    return [_REGISTRY[name] for name in sorted(_REGISTRY)]


# ---------------------------------------------------------------------------
# shared AST helpers (used by several rules)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name id of a Name/Attribute chain, else None."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def walk_functions(tree: ast.AST):
    """Yield every (sync or async) function definition in ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# runners
# ---------------------------------------------------------------------------

def iter_py_files(root: str, paths: Sequence[str]) -> Iterable[str]:
    """Repo-relative ``*.py`` paths under each entry, sorted, skipping
    __pycache__ and VCS internals."""
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full) and p.endswith(".py"):
            yield p.replace(os.sep, "/")
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in {"__pycache__", ".git", ".pytest_cache"})
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        rel = os.path.relpath(os.path.join(dirpath, fn), root)
                        yield rel.replace(os.sep, "/")


def _sorted(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))


def run_paths(paths: Optional[Sequence[str]] = None,
              rules: Optional[Sequence[str]] = None,
              root: str = REPO_ROOT) -> List[Finding]:
    """Run the selected rules over the repo.

    Per-file rules see every ``*.py`` under ``paths`` (default
    ``DEFAULT_PATHS``); repo-level rules run once regardless of
    ``paths`` (their scope is fixed by the invariant they check).
    ``# repro: allow(...)`` suppressions are applied here."""
    repo = RepoContext(root)
    selected = [r for r in all_rules() if rules is None or r.name in rules]
    if rules is not None:
        unknown = set(rules) - {r.name for r in selected}
        if unknown:
            raise ValueError(f"unknown rule(s): {sorted(unknown)}")
    out: List[Finding] = []
    file_rules = [r for r in selected if r.check_file is not None]
    for rel in iter_py_files(root, paths if paths is not None
                             else DEFAULT_PATHS):
        ctx = repo.get(rel)
        if ctx is None:
            continue
        for rule in file_rules:
            for f in rule.check_file(ctx):
                if not ctx.allowed(rule.name, f.line):
                    out.append(f)
    for rule in selected:
        if rule.check_repo is None:
            continue
        for f in rule.check_repo(repo):
            ctx = repo.get(f.path)
            if ctx is None or not ctx.allowed(rule.name, f.line):
                out.append(f)
    # a location two checks of one rule both hit reports once
    return _sorted(set(out))


def run_source(source: str, path: str,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run per-file rules over an in-memory source string (test fixture
    entry point).  ``path`` is the pretended repo-relative location --
    rules scope themselves by it (e.g. host-sync only fires under
    ``src/repro/serve/``)."""
    ctx = FileContext(path, source)
    out: List[Finding] = []
    for rule in all_rules():
        if rules is not None and rule.name not in rules:
            continue
        if rule.check_file is None:
            continue
        for f in rule.check_file(ctx):
            if not ctx.allowed(rule.name, f.line):
                out.append(f)
    return _sorted(set(out))
