"""CLI: ``python -m tools.analysis [paths...]``.

Exit 0 when every rule is clean (after baseline subtraction), 1
otherwise.  Paths are repo-relative; with none given the default scan
set is ``src benchmarks examples`` (repo-level rules -- kernel-oracle
coverage, obs-counter discipline -- always run over their fixed
scopes).

  --list-rules        print the registered rules and exit
  --rules a,b         run only the named rules
  --json              machine-readable report on stdout
  --baseline F        subtract the findings recorded in F (matching on
                      rule+path+message); new findings still fail
  --write-baseline F  dump current findings to F and exit 0
"""

from __future__ import annotations

import argparse
import json
import sys

from .core import DEFAULT_PATHS, all_rules, run_paths
from .reporters import json_report, load_baseline, text_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repro static-analysis pass (docs/analysis.md)")
    ap.add_argument("paths", nargs="*",
                    help=f"repo-relative files/dirs to scan "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--baseline", default=None, metavar="F")
    ap.add_argument("--write-baseline", default=None, metavar="F")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            kind = "/".join(k for k, c in (
                ("file", rule.check_file), ("repo", rule.check_repo)) if c)
            print(f"{rule.name}  [{kind}]  {rule.summary}")
        return 0

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    findings = run_paths(paths=args.paths or None, rules=rules)

    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as f:
            json.dump({"findings": [x.to_dict() for x in findings]},
                      f, indent=2)
        print(f"analysis: wrote baseline ({len(findings)} findings) to "
              f"{args.write_baseline}")
        return 0

    if args.baseline:
        known = set(load_baseline(args.baseline))
        findings = [f for f in findings if f.key() not in known]

    n_rules = len(all_rules() if rules is None else rules)
    if args.as_json:
        json_report(findings, sys.stdout, n_rules)
    else:
        text_report(findings, sys.stderr if findings else sys.stdout,
                    n_rules)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
