"""Text and JSON reporters for analysis findings."""

from __future__ import annotations

import json
from typing import IO, List, Sequence

from .core import Finding


def text_report(findings: Sequence[Finding], stream: IO[str],
                n_rules: int) -> None:
    for f in findings:
        stream.write(f"{f.path}:{f.line}: [{f.rule}] {f.message}\n")
    if findings:
        stream.write(f"analysis: {len(findings)} finding(s) across "
                     f"{len({f.rule for f in findings})} rule(s)\n")
    else:
        stream.write(f"analysis: OK ({n_rules} rules, 0 findings)\n")


def json_report(findings: Sequence[Finding], stream: IO[str],
                n_rules: int) -> None:
    json.dump({"rules": n_rules,
               "count": len(findings),
               "findings": [f.to_dict() for f in findings]},
              stream, indent=2)
    stream.write("\n")


def load_baseline(path: str) -> List[tuple]:
    """Baseline file: the ``findings`` list of a previous ``--json``
    run (or a ``--write-baseline`` dump).  Matching is on
    (rule, path, message) -- line numbers drift under unrelated
    edits."""
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    entries = data["findings"] if isinstance(data, dict) else data
    return [(e["rule"], e["path"], e["message"]) for e in entries]
