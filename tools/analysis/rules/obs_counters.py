"""Rule ``obs-counter-discipline``: serving-layer counters stay
registry-backed.

Migrated from the PR 8 one-off ``tools/check_obs_discipline.py`` (that
script is now a thin shim over this rule).  The serving-plane counters
(``ContinuousEngine.decode_dispatches``, ``PageHandoffChannel.handoffs``
...) read like plain attributes but are registry-backed:
``repro.obs.metrics.bind_counters`` installs data descriptors for every
name in a class's ``_COUNTERS`` tuple, so ``self.x += 1`` routes
through a ``MetricRegistry`` Counter.  That contract only holds for
DECLARED names -- an increment of an undeclared attribute silently
re-creates the pre-PR-8 world of bare counters the registry never
sees.

Fails when, across ``src/repro/serve/*.py``:

  1. a class declares ``_COUNTERS`` but never calls ``bind_counters``
     (its "counters" would be plain ints, invisible to the registry);
  2. an augmented assignment on ``self.<name>`` (or a chain rooted at
     ``self``) targets a name that is in no ``_COUNTERS`` tuple
     anywhere in the serving layer.

Allowlisted: ``epoch`` (the scheduler's page-table cache-invalidation
token -- versioning state, not a metric) and ``_``-prefixed private
state.  The declared-name set is the UNION over all serve modules (a
counter may be declared on the engine and bumped through a helper), so
this is a repo-level rule.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List

from ..core import Finding, FileContext, RepoContext, Rule, register

NAME = "obs-counter-discipline"

SERVE_DIR = "src/repro/serve"
ALLOW = frozenset({"epoch"})


def _counter_decls(tree: ast.Module):
    """Yield (class name, lineno, declared names, binds?) per class."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        names: List[str] = []
        binds = False
        for stmt in node.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id == "_COUNTERS" \
                            and isinstance(stmt.value, ast.Tuple):
                        names = [e.value for e in stmt.value.elts
                                 if isinstance(e, ast.Constant)]
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                fn = sub.func
                callee = fn.id if isinstance(fn, ast.Name) else \
                    fn.attr if isinstance(fn, ast.Attribute) else None
                if callee == "bind_counters":
                    binds = True
        yield node.name, node.lineno, names, binds


def _rooted_at_self(node: ast.expr) -> bool:
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id == "self"


def check_sources(contexts: Dict[str, FileContext]) -> List[Finding]:
    """The two checks over a {path -> FileContext} map (exposed so
    tests can run fixture modules through the real logic)."""
    out: List[Finding] = []
    declared: set = set()
    for path, ctx in contexts.items():
        for cls, lineno, names, binds in _counter_decls(ctx.tree):
            declared.update(names)
            if names and not binds:
                out.append(Finding(
                    NAME, path, lineno,
                    f"class {cls} declares _COUNTERS but never calls "
                    f"bind_counters -- its counters are bare ints the "
                    f"MetricRegistry cannot see"))
    for path, ctx in contexts.items():
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.AugAssign)
                    and isinstance(node.target, ast.Attribute)):
                continue
            attr = node.target.attr
            if attr.startswith("_") or attr in ALLOW or attr in declared:
                continue
            if not _rooted_at_self(node.target.value):
                continue        # request/local object state, not a counter
            out.append(Finding(
                NAME, path, node.lineno,
                f"'self...{attr} (op)=' mutates a bare attribute "
                f"declared in no _COUNTERS tuple; declare it "
                f"(registry-backed via bind_counters) or rename it "
                f"_{attr} if it is private state"))
    return out


def check_repo(repo: RepoContext) -> Iterable[Finding]:
    contexts: Dict[str, FileContext] = {}
    for fn in repo.listdir(SERVE_DIR):
        if fn.endswith(".py"):
            ctx = repo.get(f"{SERVE_DIR}/{fn}")
            if ctx is not None:
                contexts[ctx.path] = ctx
    return check_sources(contexts)


register(Rule(
    name=NAME,
    summary=("serving-layer self.<counter> (op)= targets must be "
             "declared in a _COUNTERS tuple and bound through "
             "bind_counters (registry-backed)"),
    check_repo=check_repo,
))
