"""Rule ``donation-safety``: never read a donated buffer after the
jitted call that consumed it.

``donate_argnums`` hands a buffer's storage to XLA: after the call the
Python reference still exists but the array is DELETED -- touching it
raises (best case) or, with buffer aliasing on some backends, reads
bytes the kernel already overwrote.  The serving plane donates the
pool cache into every decode dispatch and the prefill carry into every
``_ctx_write``; the invariant that nothing reads those operands
afterwards is what this rule pins.

Per file (scanned under ``serve/``, ``train/``, ``launch/`` and
``benchmarks/``):

  1. collect donating callables: ``X = jax.jit(fn, donate_argnums=...)``
     assignments (incl. ``self._x`` targets) and functions decorated
     ``@functools.partial(jax.jit, donate_argnums=...)``;
  2. at each call site of a collected callable, take the donated
     positional args that are plain names (``state``) or constant-key
     subscripts (``ctx["k"]``);
  3. flag any LOAD of such an operand in the statements after the call
     (same statement list) before it is reassigned.  The canonical
     rebind idiom ``state = loop(..., state)`` stops tracking -- the
     name now holds the NEW buffer.

The tracker is deliberately statement-local and alias-free: it will
miss a donated read smuggled through an alias, but it never flags the
legitimate rebind patterns the engines use.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..core import Finding, FileContext, Rule, dotted_name, register

NAME = "donation-safety"

_SCOPES = ("src/repro/serve/", "src/repro/train/", "src/repro/launch/",
           "benchmarks/")

# a tracked operand: ("name", None) for a bare name, ("name", key) for
# name[key] with a constant key
Operand = Tuple[str, Optional[object]]


def _donated_positions(call: ast.Call) -> Optional[List[int]]:
    """donate_argnums positions of a ``jax.jit(...)`` call, or None if
    the call is not a donating jit."""
    if dotted_name(call.func) not in ("jax.jit", "jit"):
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            out = [e.value for e in v.elts
                   if isinstance(e, ast.Constant)
                   and isinstance(e.value, int)]
            return out or None
    return None


def _partial_jit_positions(deco: ast.AST) -> Optional[List[int]]:
    """donate_argnums of a ``functools.partial(jax.jit, ...)``
    decorator, else None."""
    if not isinstance(deco, ast.Call):
        return None
    if dotted_name(deco.func) not in ("functools.partial", "partial"):
        return None
    if not deco.args or dotted_name(deco.args[0]) not in ("jax.jit", "jit"):
        return None
    for kw in deco.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)] or None
    return None


def _collect_donors(tree: ast.AST) -> Dict[str, List[int]]:
    """{callable short name -> donated positions} for this file."""
    donors: Dict[str, List[int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            positions = (_donated_positions(node.value)
                         if isinstance(node.value, ast.Call) else None)
            if positions:
                for tgt in node.targets:
                    name = tgt.id if isinstance(tgt, ast.Name) else (
                        tgt.attr if isinstance(tgt, ast.Attribute) else None)
                    if name:
                        donors[name] = positions
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for deco in node.decorator_list:
                positions = _partial_jit_positions(deco)
                if positions:
                    donors[node.name] = positions
    return donors


def _operand(arg: ast.AST) -> Optional[Operand]:
    if isinstance(arg, ast.Name):
        return (arg.id, None)
    if isinstance(arg, ast.Subscript) and isinstance(arg.value, ast.Name) \
            and isinstance(arg.slice, ast.Constant):
        return (arg.value.id, arg.slice.value)
    return None


def _donating_calls(stmt: ast.stmt, donors: Dict[str, List[int]]):
    """Yield (call, donated operands) for donor calls inside ``stmt``."""
    for node in ast.walk(stmt):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        name = func.id if isinstance(func, ast.Name) else (
            func.attr if isinstance(func, ast.Attribute) else None)
        if name not in donors:
            continue
        ops = []
        for pos in donors[name]:
            if pos < len(node.args):
                op = _operand(node.args[pos])
                if op is not None:
                    ops.append(op)
        if ops:
            yield node, name, ops


def _stores_of(stmt: ast.stmt) -> Set[Operand]:
    """Operands ``stmt`` (re)binds: bare names and const-key subscripts
    in Store context."""
    stores: Set[Operand] = set()
    for node in ast.walk(stmt):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            stores.add((node.id, None))
        elif isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Store) \
                and isinstance(node.value, ast.Name) \
                and isinstance(node.slice, ast.Constant):
            stores.add((node.value.id, node.slice.value))
    return stores


def _loads_of(stmt: ast.stmt, tracked: Set[Operand]):
    """Yield (operand, lineno) for loads of tracked operands in
    ``stmt``.  A bare-name track hits any load of the name; a
    subscript track hits only the same constant key."""
    for node in ast.walk(stmt):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Load) \
                and isinstance(node.value, ast.Name) \
                and isinstance(node.slice, ast.Constant):
            op = (node.value.id, node.slice.value)
            if op in tracked:
                yield op, node.lineno
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            # skip the base name of a const-key subscript handled above
            op = (node.id, None)
            if op in tracked:
                yield op, node.lineno


def _apply_stores(tracked: Set[Operand], stores: Set[Operand]) -> None:
    """Drop tracked operands a statement rebinds.  A store of the bare
    base name also kills subscript tracks rooted at it (the dict/list
    binding changed wholesale)."""
    for base, key in list(tracked):
        if (base, None) in stores or (base, key) in stores:
            tracked.discard((base, key))


def _check_body(ctx: FileContext, body: List[ast.stmt],
                donors: Dict[str, List[int]]) -> Iterable[Finding]:
    for i, stmt in enumerate(body):
        tracked: Set[Operand] = set()
        donor_name = None
        for call, name, ops in _donating_calls(stmt, donors):
            donor_name = name
            tracked.update(ops)
        if tracked:
            # the canonical rebind: `state = loop(..., state)` -- the
            # donated operand's binding now holds the returned buffer
            _apply_stores(tracked, _stores_of(stmt))
        for later in body[i + 1:]:
            if not tracked:
                break
            for op, lineno in _loads_of(later, tracked):
                base, key = op
                shown = base if key is None else f"{base}[{key!r}]"
                yield Finding(
                    NAME, ctx.path, lineno,
                    f"`{shown}` was donated to `{donor_name}` (line "
                    f"{stmt.lineno}) -- its buffer no longer exists after "
                    f"the call; use the returned value, or drop "
                    f"donate_argnums if the operand must stay readable")
                tracked.discard(op)
            _apply_stores(tracked, _stores_of(later))


def check_file(ctx: FileContext) -> List[Finding]:
    if not any(ctx.path.startswith(s) for s in _SCOPES):
        return []
    donors = _collect_donors(ctx.tree)
    if not donors:
        return []
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        body = getattr(node, "body", None)
        if isinstance(body, list) and body \
                and isinstance(body[0], ast.stmt):
            out.extend(_check_body(ctx, body, donors))
            orelse = getattr(node, "orelse", None)
            if isinstance(orelse, list) and orelse:
                out.extend(_check_body(ctx, orelse, donors))
    return out


register(Rule(
    name=NAME,
    summary=("no read of a donate_argnums-donated operand after the "
             "jitted call that consumed it (serve/, train/, launch/, "
             "benchmarks/)"),
    check_file=check_file,
))
