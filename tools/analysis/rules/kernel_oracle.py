"""Rule ``kernel-oracle``: every public Pallas kernel ships a ref
oracle and an XLA fallback, and the dispatch actually wires them.

The kernel contract (ROADMAP): each ``*_pallas`` entry point is
cross-checked against a pure-jnp oracle in ``kernels/ref.py`` (the
numerics ground truth tests diff against) and has an XLA-only fallback
the serving plane can lower when Pallas is unavailable (the dry-run /
``use_ref`` / ``decode_impl="blocked"`` paths).  A kernel landed
without its oracle+fallback pair silently narrows every downstream
parity test to "Pallas agrees with itself".

``KERNEL_TABLE`` is the explicit registry of those triples.  The rule
checks, against the live tree:

  1. every public ``*_pallas`` def under ``src/repro/kernels/`` has a
     table entry (discovery: top-level non-underscore defs, ref.py and
     __init__.py excluded);
  2. the oracle exists as a def in ``kernels/ref.py``;
  3. the fallback exists as a def in its module;
  4. the fallback module references the kernel by name -- i.e. the
     dispatch choosing kernel-vs-fallback lives where the table says;
  5. stale table entries (kernel deleted/renamed) are flagged too, so
     the table cannot rot into documentation.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from ..core import Finding, RepoContext, Rule, register

NAME = "kernel-oracle"

KERNELS_DIR = "src/repro/kernels"
REF_PATH = "src/repro/kernels/ref.py"

#: kernel -> (oracle def in kernels/ref.py, fallback module, fallback def)
#: The oracle name is NOT derived from the kernel name on purpose:
#: ``paged_flash_prefill_pallas``'s oracle is ``paged_prefill_ref``,
#: and an explicit table is what lets the rule flag a rename on either
#: side instead of silently un-pairing them.
KERNEL_TABLE: Dict[str, Tuple[str, str, str]] = {
    "rmmec_matmul_pallas": (
        "rmmec_matmul_ref", "src/repro/kernels/ops.py", "packed_matmul"),
    "quire_dot_pallas": (
        "quire_dot_ref", "src/repro/kernels/ops.py", "quire_dot"),
    "dequant_pallas": (
        "dequant_ref", "src/repro/kernels/ops.py", "to_dense"),
    "flash_decode_pallas": (
        "flash_decode_ref", "src/repro/models/attention.py",
        "decode_quantized_blocks"),
    "paged_flash_decode_pallas": (
        "paged_flash_decode_ref", "src/repro/models/attention.py",
        "paged_decode_blocked"),
    "paged_flash_prefill_pallas": (
        "paged_prefill_ref", "src/repro/models/attention.py",
        "paged_prefill_blocked"),
}


def _top_level_defs(tree: ast.Module) -> Dict[str, int]:
    return {node.name: node.lineno for node in tree.body
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}


def discover_kernels(repo: RepoContext) -> Dict[str, Tuple[str, int]]:
    """{kernel name -> (module path, def line)} for every public
    ``*_pallas`` top-level def under ``src/repro/kernels/``."""
    out: Dict[str, Tuple[str, int]] = {}
    for fn in repo.listdir(KERNELS_DIR):
        if not fn.endswith(".py") or fn in ("__init__.py", "ref.py"):
            continue
        ctx = repo.get(f"{KERNELS_DIR}/{fn}")
        if ctx is None:
            continue
        for name, lineno in _top_level_defs(ctx.tree).items():
            if name.endswith("_pallas") and not name.startswith("_"):
                out[name] = (ctx.path, lineno)
    return out


def check_table(repo: RepoContext,
                table: Dict[str, Tuple[str, str, str]]) -> List[Finding]:
    """Validate ``table`` against the live tree (exposed separately so
    tests can inject a broken table)."""
    out: List[Finding] = []
    kernels = discover_kernels(repo)
    ref_ctx = repo.get(REF_PATH)
    ref_defs = _top_level_defs(ref_ctx.tree) if ref_ctx else {}
    for name, (path, lineno) in sorted(kernels.items()):
        if name not in table:
            out.append(Finding(
                NAME, path, lineno,
                f"public kernel `{name}` has no KERNEL_TABLE entry "
                f"(tools/analysis/rules/kernel_oracle.py): every "
                f"*_pallas entry point must register its ref.py oracle "
                f"and XLA fallback"))
    for name, (oracle, fb_path, fb_name) in sorted(table.items()):
        if name not in kernels:
            out.append(Finding(
                NAME, f"{KERNELS_DIR}/__init__.py", 1,
                f"stale KERNEL_TABLE entry `{name}`: no such public "
                f"kernel under {KERNELS_DIR}/ -- update the table with "
                f"the rename/removal"))
            continue
        k_path, k_line = kernels[name]
        if oracle not in ref_defs:
            out.append(Finding(
                NAME, k_path, k_line,
                f"kernel `{name}` declares oracle `{oracle}` but "
                f"{REF_PATH} defines no such function"))
        fb_ctx = repo.get(fb_path)
        fb_defs = _top_level_defs(fb_ctx.tree) if fb_ctx else {}
        if fb_name not in fb_defs:
            out.append(Finding(
                NAME, k_path, k_line,
                f"kernel `{name}` declares XLA fallback "
                f"`{fb_path}:{fb_name}` but that module defines no such "
                f"function"))
        elif fb_ctx is not None and name not in fb_ctx.source:
            out.append(Finding(
                NAME, fb_path, fb_defs[fb_name],
                f"fallback module {fb_path} never references kernel "
                f"`{name}`: the kernel-vs-fallback dispatch the table "
                f"claims does not exist there"))
    return out


def check_repo(repo: RepoContext) -> Iterable[Finding]:
    return check_table(repo, KERNEL_TABLE)


register(Rule(
    name=NAME,
    summary=("every public *_pallas kernel has a kernels/ref.py oracle "
             "and an XLA fallback, cross-checked against the dispatch "
             "site"),
    check_repo=check_repo,
))
