"""Rule ``determinism``: scheduler decisions are a pure function of the
request stream, and interval timing uses a monotonic clock.

The parity ladders (interleaved vs. disaggregated, K-invariance,
prefix-cache on/off) only hold because admission order, victim choice
and batch composition depend on nothing but the submitted requests.
A wall clock or RNG in a decision path silently breaks replay; an
unsorted set iteration feeding admission/batch order breaks it across
Python hash seeds.

Checks:

  1. in ``src/repro/serve/scheduler.py`` (the decision paths --
     admission, capacity, preemption, prefix index, decode runner):
     flag ``time.time``/``time.monotonic``, ``random.*``,
     ``np.random.*`` and ``os.urandom`` calls.  ``time.perf_counter``
     stays legal: the telemetry plane stamps spans with it, and
     tracing never feeds decisions;
  2. in every serving module (``src/repro/serve/``): flag for-loops
     iterating a set display / ``set(...)`` / ``frozenset(...)`` /
     set comprehension directly -- iteration order is hash-seed
     dependent; wrap in ``sorted(...)``;
  3. anywhere in the scanned tree: flag ``time.time()`` -- it is
     wall-clock (NTP steps move it backwards); intervals must use
     ``time.perf_counter``.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..core import Finding, FileContext, Rule, dotted_name, register

NAME = "determinism"

_SCHED_BANNED = ("time.time", "time.monotonic", "os.urandom")
_SCHED_BANNED_PREFIX = ("random.", "np.random.", "numpy.random.",
                        "secrets.")


def _scheduler_calls(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        dn = dotted_name(node.func)
        if dn is None:
            continue
        if dn in _SCHED_BANNED or any(dn.startswith(p)
                                      for p in _SCHED_BANNED_PREFIX):
            yield Finding(
                NAME, ctx.path, node.lineno,
                f"`{dn}(...)` in a scheduler decision path: admission/"
                f"preemption/batch order must be a pure function of the "
                f"request stream (the parity ladders replay it); derive "
                f"randomness from a seeded per-request stream and timing "
                f"from the obs plane")


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return dotted_name(node.func) in ("set", "frozenset")
    return False


def _set_iteration(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.For, ast.AsyncFor)) \
                and _is_set_expr(node.iter):
            yield Finding(
                NAME, ctx.path, node.iter.lineno,
                "iterating a set in the serving layer: order is "
                "hash-seed dependent, so anything it feeds (admission, "
                "batch rows, page assignment) diverges across runs; "
                "wrap in sorted(...) or keep a list/deque")
        elif isinstance(node, (ast.ListComp, ast.SetComp,
                               ast.GeneratorExp, ast.DictComp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    yield Finding(
                        NAME, ctx.path, gen.iter.lineno,
                        "comprehension over a set in the serving layer: "
                        "order is hash-seed dependent; wrap in "
                        "sorted(...)")


def _wall_clock(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) \
                and dotted_name(node.func) == "time.time":
            yield Finding(
                NAME, ctx.path, node.lineno,
                "`time.time()` is wall-clock (non-monotonic under NTP "
                "steps); use time.perf_counter for intervals")


def check_file(ctx: FileContext) -> List[Finding]:
    out: List[Finding] = []
    if ctx.path == "src/repro/serve/scheduler.py":
        out.extend(_scheduler_calls(ctx))
    if ctx.path.startswith("src/repro/serve/"):
        out.extend(_set_iteration(ctx))
    out.extend(_wall_clock(ctx))
    return out


register(Rule(
    name=NAME,
    summary=("no wall-clock/RNG in scheduler decision paths, no "
             "unsorted set iteration in the serving layer, "
             "time.perf_counter over time.time everywhere"),
    check_file=check_file,
))
