"""Rule ``jit-in-step``: never construct a jitted callable (or a
``pl.pallas_call``) inside a per-step loop or a serving ``step()``
body.

``jax.jit`` returns a FRESH callable with its own trace cache: built
inside a loop, every iteration traces, lowers and compiles from
scratch -- the steady-state-recompile regression the compile-count
sentinel (``ContinuousEngine.trace_counts``) exists to catch at
runtime.  This rule catches it at the diff: jit/pallas_call
construction belongs in ``__init__``/``__post_init__``/builders, where
it runs once and the trace cache amortizes.

Flagged (scope: ``src/repro/``):

  * ``jax.jit(...)`` / ``pl.pallas_call(...)`` /
    ``functools.partial(jax.jit, ...)`` lexically inside a for/while
    body anywhere;
  * the same constructions anywhere inside a serving-layer ``step``,
    ``dispatch`` or ``sync`` method (``src/repro/serve/``) -- those run
    once per engine step, which IS the loop.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..core import (Finding, FileContext, Rule, dotted_name, register,
                    walk_functions)

NAME = "jit-in-step"

_STEP_FUNCTIONS = frozenset({"step", "dispatch", "sync"})
_CONSTRUCTORS = ("jax.jit", "pl.pallas_call", "pallas_call")


def _construction(node: ast.AST):
    """The constructor's dotted name if ``node`` builds a jitted
    callable, else None."""
    if not isinstance(node, ast.Call):
        return None
    dn = dotted_name(node.func)
    if dn in _CONSTRUCTORS:
        return dn
    if dn in ("functools.partial", "partial") and node.args \
            and dotted_name(node.args[0]) in ("jax.jit", "jit"):
        return "functools.partial(jax.jit, ...)"
    return None


def _flag_constructions(ctx: FileContext, root: ast.AST,
                        where: str) -> Iterable[Finding]:
    for node in ast.walk(root):
        ctor = _construction(node)
        if ctor is not None:
            yield Finding(
                NAME, ctx.path, node.lineno,
                f"`{ctor}` constructed {where}: every execution traces "
                f"and compiles from scratch (a guaranteed steady-state "
                f"recompile); hoist the construction to "
                f"__init__/__post_init__ or a module-level builder")


def check_file(ctx: FileContext) -> List[Finding]:
    if not ctx.path.startswith("src/repro/"):
        return []
    out: List[Finding] = []
    seen = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            for stmt in node.body + node.orelse:
                for f in _flag_constructions(ctx, stmt,
                                             "inside a loop body"):
                    if f.line not in seen:
                        seen.add(f.line)
                        out.append(f)
    if ctx.path.startswith("src/repro/serve/"):
        for fn in walk_functions(ctx.tree):
            if fn.name in _STEP_FUNCTIONS:
                for f in _flag_constructions(
                        ctx, fn, f"inside step-path `{fn.name}`"):
                    if f.line not in seen:
                        seen.add(f.line)
                        out.append(f)
    return out


register(Rule(
    name=NAME,
    summary=("no jax.jit / pl.pallas_call construction inside per-step "
             "loops or serving step()/dispatch()/sync() bodies"),
    check_file=check_file,
))
