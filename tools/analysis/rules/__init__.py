"""Rule modules self-register on import; importing this package is
what populates ``tools.analysis.core``'s registry (``all_rules``
imports it lazily, so rule modules can import core freely)."""

from . import determinism   # noqa: F401
from . import donation      # noqa: F401
from . import host_sync     # noqa: F401
from . import kernel_oracle  # noqa: F401
from . import obs_counters  # noqa: F401
from . import retrace       # noqa: F401
