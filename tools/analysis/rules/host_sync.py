"""Rule ``host-sync``: the decode/step critical path must not block on
implicit device->host transfers.

The serving plane's latency model budgets exactly ONE host sync per
engine step -- the explicit ``jax.device_get`` of the (B, K) sampled
tokens.  Anything else that forces a transfer inside the step path
(``.item()``, ``np.asarray`` on a device value, ``int()/float()`` on a
jnp result, printing a device array) serializes host and device and
shows up as an unattributable p99 shift, not a test failure.

Two checks:

  1. inside the serving layer's HOT functions (``step``, ``dispatch``,
     ``sync``, the prefill/dispatch helpers), flag ``.item()``,
     ``np.asarray`` / ``np.array``, ``print``, and ``int/float/bool``
     applied to a value produced by a ``jnp.``/``jax.``/``lax.`` call
     (``jax.device_get`` is the sanctioned explicit escape and is
     never flagged);
  2. anywhere in the scanned tree, flag ``int/float/bool`` wrapping a
     ``jnp.``/``jax.``-rooted call lexically inside a for/while loop:
     a device sync per iteration.  Accumulate on device and convert
     once after the loop.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set

from ..core import (Finding, FileContext, Rule, dotted_name, register,
                    root_name, walk_functions)

NAME = "host-sync"

#: step/dispatch-path functions of the serving layer (engine.py /
#: disagg.py).  ``_sample`` is deliberately absent: it is the sanctioned
#: HOST twin of the fused sampler, called once per request at prefill
#: completion, and its int(...) syncs are its contract.
HOT_FUNCTIONS = frozenset({
    "step", "dispatch", "sync", "admit_handoffs",
    "_prefill_chunk", "_prefill_phase", "_dispatch_decode_loop",
    "_apply_decode_tokens", "_drain_ready",
})

_DEVICE_ROOTS = frozenset({"jnp", "jax", "lax"})
#: calls that RETURN host values (or metadata) despite a device root
_HOST_RETURNING = ("jax.device_get", "jnp.finfo", "jnp.iinfo",
                   "jax.eval_shape")
_CASTS = frozenset({"int", "float", "bool"})
_NP_SYNCS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                       "numpy.array", "onp.asarray", "onp.array"})


def _is_device_call(node: ast.AST) -> bool:
    """True for a Call rooted at jnp/jax/lax that returns a device
    value (``jax.device_get`` etc. excluded)."""
    if not isinstance(node, ast.Call):
        return False
    dn = dotted_name(node.func)
    if dn is None or root_name(node.func) not in _DEVICE_ROOTS:
        return False
    return not any(dn == h or dn.startswith(h + ".")
                   for h in _HOST_RETURNING)


def _device_bound_names(fn: ast.AST) -> Set[str]:
    """Names assigned (anywhere in ``fn``) from a device-returning
    jnp/jax call -- the conservative alias set the int/float check
    consults."""
    bound: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _is_device_call(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    bound.add(tgt.id)
    return bound


def _check_hot_function(ctx: FileContext, fn) -> Iterable[Finding]:
    device_names = _device_bound_names(fn)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        dn = dotted_name(func)
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not node.args:
            yield Finding(NAME, ctx.path, node.lineno,
                          f"`.item()` in step-path `{fn.name}` blocks on a "
                          f"device->host transfer; keep the value on device "
                          f"or use the step's one sanctioned "
                          f"jax.device_get sync")
        elif dn in _NP_SYNCS:
            yield Finding(NAME, ctx.path, node.lineno,
                          f"`{dn}(...)` in step-path `{fn.name}` implicitly "
                          f"syncs if handed a device value; use "
                          f"jax.device_get for the sanctioned sync (host "
                          f"arrays: build them outside the hot path)")
        elif isinstance(func, ast.Name) and func.id == "print":
            yield Finding(NAME, ctx.path, node.lineno,
                          f"`print(...)` in step-path `{fn.name}`: printing "
                          f"a device value forces a blocking transfer (and "
                          f"host I/O) on the decode critical path; use the "
                          f"obs trace/metrics plane instead")
        elif isinstance(func, ast.Name) and func.id in _CASTS and node.args:
            arg = node.args[0]
            is_device = _is_device_call(arg) or (
                isinstance(arg, ast.Name) and arg.id in device_names) or (
                isinstance(arg, ast.Subscript)
                and isinstance(arg.value, ast.Name)
                and arg.value.id in device_names)
            if is_device:
                yield Finding(
                    NAME, ctx.path, node.lineno,
                    f"`{func.id}(...)` on a device value in step-path "
                    f"`{fn.name}` blocks on the transfer; sync once via "
                    f"jax.device_get and convert the host copy")


def _check_casts_in_loops(ctx: FileContext) -> Iterable[Finding]:
    loops = [n for n in ast.walk(ctx.tree)
             if isinstance(n, (ast.For, ast.While, ast.AsyncFor))]
    seen: Set[int] = set()
    for loop in loops:
        for stmt in loop.body + loop.orelse:
            for node in ast.walk(stmt):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id in _CASTS and node.args):
                    continue
                if node.lineno in seen or not _is_device_call(node.args[0]):
                    continue
                seen.add(node.lineno)
                yield Finding(
                    NAME, ctx.path, node.lineno,
                    f"`{node.func.id}(jnp...)` inside a loop syncs the "
                    f"device every iteration; accumulate on device (or "
                    f"collect device scalars) and convert once after the "
                    f"loop")


def check_file(ctx: FileContext) -> List[Finding]:
    out: List[Finding] = []
    if ctx.path.startswith("src/repro/serve/"):
        for fn in walk_functions(ctx.tree):
            if fn.name in HOT_FUNCTIONS:
                out.extend(_check_hot_function(ctx, fn))
    out.extend(_check_casts_in_loops(ctx))
    return out


register(Rule(
    name=NAME,
    summary=("no implicit device->host sync (.item(), np.asarray, "
             "int()/float() on device values, print) in serve step paths "
             "or per-iteration in loops"),
    check_file=check_file,
))
