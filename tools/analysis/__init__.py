"""repro static-analysis framework: registered AST rules enforcing the
serving plane's performance/determinism disciplines.

CLI: ``python -m tools.analysis [paths...] [--json] [--baseline F]``
(run from the repo root).  See ``docs/analysis.md`` for the rule
catalog and ``tools/analysis/core.py`` for the framework contract.
"""

from .core import (DEFAULT_PATHS, FileContext, Finding, RepoContext, Rule,
                   all_rules, register, run_paths, run_source)

__all__ = ["DEFAULT_PATHS", "FileContext", "Finding", "RepoContext",
           "Rule", "all_rules", "register", "run_paths", "run_source"]
