"""Sharded, atomic, fault-tolerant checkpointing (no orbax: built here).

Layout:
  <dir>/step_<N>/manifest.json   -- paths, shapes, dtypes, data-iterator
                                    state, mesh shape at save time, and
                                    versioned PackedTensor aux (format /
                                    logical shape / scale group) so packed
                                    serving trees round-trip
  <dir>/step_<N>/<leaf-path>.npy -- one file per pytree leaf

Guarantees exercised by tests:
  * atomic commit: writes go to ``step_N.tmp`` then os.rename -- a crash
    mid-save never corrupts the latest checkpoint;
  * exact resume: data iterator state rides in the manifest;
  * elastic restore: leaves are device_put against the *current* mesh's
    shardings, which may differ from the mesh at save time (N->M chips);
  * corruption detection: per-leaf byte size is recorded and verified;
  * retention: keep the newest K checkpoints.

Async: ``CheckpointManager(async_save=True)`` snapshots to host numpy and
writes on a background thread -- the train loop never blocks on disk.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..core.policy import flatten_with_paths


def _is_packed(node) -> bool:
    return hasattr(node, "words") and hasattr(node, "scales")


def _packed_aux(tree) -> Dict[str, Dict[str, Any]]:
    """Versioned aux metadata of every PackedTensor node: the layout
    info (format, logical shape, scale group, version) that the array
    leaves alone cannot reconstruct.  Keyed by tree path -- the SAME
    traversal as the leaf files (flatten_with_paths), so keys always
    line up with restore's rebuild."""
    return {
        path: {
            "spec": node.spec.name,
            "shape": list(node.shape),
            "group": node.group,
            "version": getattr(node, "version", 1),
        }
        for path, node in flatten_with_paths(tree, keep_packed=True)
        if _is_packed(node)
    }

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _leaf_file(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {},
                                "packed": _packed_aux(tree)}
    for path, leaf in flatten_with_paths(tree):
        arr = np.asarray(leaf)
        fname = _leaf_file(path)
        dtype_str = str(arr.dtype)
        if dtype_str == "bfloat16":  # numpy can't round-trip ml_dtypes
            np.save(os.path.join(tmp, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype_str,
            "nbytes": int(arr.nbytes),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching pytree of NamedShardings -- leaves are
    device_put against them (elastic restore onto a different mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = flatten_with_paths(template)
    shard_map = dict(flatten_with_paths(shardings)) if shardings is not None \
        else {}
    restored = {}
    for path, tleaf in flat_t:
        meta = manifest["leaves"].get(path)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        fpath = os.path.join(base, meta["file"])
        arr = np.load(fpath)
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if int(arr.nbytes) != meta["nbytes"]:
            raise IOError(f"corrupted checkpoint leaf {path}: "
                          f"{arr.nbytes} != {meta['nbytes']}")
        if shard_map.get(path) is not None:
            restored[path] = jax.device_put(arr, shard_map[path])
        else:
            restored[path] = jax.numpy.asarray(arr)

    packed_meta = manifest.get("packed", {})

    def rebuild(node, path=""):
        if isinstance(node, dict):
            return {k: rebuild(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rebuild(v, f"{path}/{i}" if path else str(i))
                              for i, v in enumerate(node))
        if node is None:
            return None
        if _is_packed(node):
            # array leaves from disk + aux (spec/shape/group/version) from
            # the manifest -- the saved layout wins over the template's,
            # so checkpoints round-trip across layout evolution
            new = dataclasses.replace(node,
                                      words=restored[f"{path}/words"],
                                      scales=restored[f"{path}/scales"],
                                      mask=restored[f"{path}/mask"])
            meta = packed_meta.get(path)
            if meta is not None:
                from ..core.formats import format_by_name
                new = dataclasses.replace(
                    new, spec=format_by_name(meta["spec"]),
                    shape=tuple(meta["shape"]), group=meta.get("group"),
                    version=meta.get("version", 1))
            return new
        if dataclasses.is_dataclass(node) and not isinstance(node, type):
            return type(node)(**{
                f.name: rebuild(getattr(node, f.name),
                                f"{path}/{f.name}" if path else f.name)
                for f in dataclasses.fields(node)})
        return restored[path]

    return rebuild(template), manifest["extra"], step


class CheckpointManager:
    """Retention + optional async save + resume helper."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        if self._error:
            raise self._error
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
        if not self.async_save:
            save_checkpoint(self.directory, step, host_tree, extra, self.keep)
            return

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra,
                                self.keep)
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, shardings=None):
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)
