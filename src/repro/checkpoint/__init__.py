from .ckpt import save_checkpoint, restore_checkpoint, latest_step, \
    CheckpointManager  # noqa: F401
