from .loop import TrainState, build_train_step, train_loop, make_policy, \
    init_state  # noqa: F401
