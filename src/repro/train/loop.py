"""Training loop: QAT, microbatch accumulation, compression, recovery.

``build_train_step`` assembles the jitted step for a (ModelConfig,
RunConfig) pair:

  fake-quant params per PrecisionPolicy (QAT plane, STE)      [paper]
  -> loss/grad (scan-over-layers model, remat per config)
  -> per-microbatch gradient accumulation (lax.scan)          [overlap: the
     per-microbatch reduce-scatter pattern is overlappable on real HW]
  -> posit8 gradient compression with error feedback          [paper-aligned]
  -> global-norm clip -> warmup-cosine LR -> AdamW (8-bit opt)

``train_loop`` adds checkpoint/restart (atomic, async), preemption
recovery (any step may raise; we restore and continue), and straggler
mitigation hooks (deterministic data re-sharding).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs.base import ModelConfig, RunConfig
from ..core import sensitivity
from ..core.policy import PrecisionPolicy
from ..models import zoo
from ..optim import OptConfig, adamw_init, adamw_update, warmup_cosine
from ..parallel import collectives
from ..parallel.sharding import (batch_pspec, param_sharding_tree, use_mesh)

__all__ = ["TrainState", "build_train_step", "train_loop", "make_policy",
           "init_state"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any
    residuals: Any  # grad-compression error feedback (None if unused)

    def tree_flatten(self):
        return (self.step, self.params, self.opt_state, self.residuals), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_policy(run: RunConfig, params=None, grads=None) -> PrecisionPolicy:
    name = run.precision_policy
    if name == "mixed":
        return PrecisionPolicy.paper_mixed()
    if name == "adaptive":
        assert params is not None and grads is not None, \
            "adaptive policy needs a calibration gradient"
        return sensitivity.assign_layer_adaptive(
            params, grads, target_avg_bits=run.target_avg_bits)
    return PrecisionPolicy.uniform(name)


def init_state(key, cfg: ModelConfig, run: RunConfig) -> TrainState:
    params = zoo.init_model(key, cfg)
    opt_cfg = OptConfig(weight_decay=run.weight_decay,
                        moment_dtype=run.opt_state_dtype)
    opt_state = adamw_init(params, opt_cfg)
    residuals = (jax.tree.map(jnp.zeros_like, params)
                 if run.grad_compression == "posit8" else None)
    return TrainState(jnp.zeros((), jnp.int32), params, opt_state, residuals)


def build_train_step(cfg: ModelConfig, run: RunConfig,
                     policy: Optional[PrecisionPolicy] = None,
                     mesh=None, donate: bool = True):
    """Returns jitted ``(state, batch) -> (state, metrics)``."""
    opt_cfg = OptConfig(weight_decay=run.weight_decay,
                        moment_dtype=run.opt_state_dtype)
    policy = policy or make_policy(run)
    use_qat = run.qat and policy.default != "fp32"

    def loss_fn(params, batch):
        # QAT happens per-layer inside the scan body (policy threaded in),
        # so only one layer's quantized copy is live at a time.
        return zoo.loss_fn(params, batch, cfg,
                           policy=policy if use_qat else None)

    def grads_of(params, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, loss, ce, aux

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params = state.params
        if run.microbatch > 1:
            # UNROLLED accumulation (python loop, not lax.scan): each
            # microbatch's reduce-scatter is separately schedulable
            # (compute/comm overlap on real HW), and the dry-run's
            # cost_analysis sees every microbatch's FLOPs (a scan body
            # is only counted once by XLA's analysis).
            mb = run.microbatch

            def slice_mb(x, i):
                b = x.shape[0] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            grads = loss = ce = aux = None
            for i in range(mb):
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                g, l, c, a = grads_of(params, mb_batch)
                if grads is None:
                    grads, loss, ce, aux = g, l, c, a
                else:
                    grads = jax.tree.map(jnp.add, grads, g)
                    loss, ce, aux = loss + l, ce + c, aux + a
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss, ce, aux = loss / mb, ce / mb, aux / mb
        else:
            grads, loss, ce, aux = grads_of(params, batch)

        residuals = state.residuals
        if run.grad_compression == "posit8":
            grads, residuals = collectives.error_feedback_update(
                grads, residuals)

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-9)) \
            if run.grad_clip > 0 else 1.0
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = warmup_cosine(state.step, run.lr, run.warmup_steps, run.steps)
        new_params, new_opt = adamw_update(params, grads, state.opt_state,
                                           lr, opt_cfg)
        new_state = TrainState(state.step + 1, new_params, new_opt, residuals)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm,
                   "lr": lr}
        return new_state, metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    # production path: explicit shardings
    def shard_state(state):
        return TrainState(
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            param_sharding_tree(mesh, state.params),
            param_sharding_tree(mesh, state.opt_state),
            param_sharding_tree(mesh, state.residuals)
            if state.residuals is not None else None,
        )
    return step_fn, shard_state  # caller lowers with explicit shardings


def train_loop(cfg: ModelConfig, run: RunConfig, data,
               state: Optional[TrainState] = None,
               policy: Optional[PrecisionPolicy] = None,
               log_every: int = 10,
               hooks: Optional[Dict[str, Callable]] = None) -> Tuple[
                   TrainState, Dict[str, list]]:
    """Single-host training driver with checkpoint/restart."""
    hooks = hooks or {}
    mgr = CheckpointManager(run.checkpoint_dir, keep=run.keep_checkpoints,
                            async_save=True)
    if state is None:
        state = init_state(jax.random.PRNGKey(run.seed), cfg, run)
    # resume if a checkpoint exists
    if mgr.latest_step() is not None:
        state, extra, at = mgr.restore(state)
        if "data" in extra:
            data.load_state_dict(extra["data"])
        print(f"[train] resumed from step {at}")

    step_fn = build_train_step(cfg, run, policy)
    history: Dict[str, list] = {"loss": [], "ce": [], "step": []}
    t0 = time.perf_counter()      # monotonic: immune to NTP clock steps
    while int(state.step) < run.steps:
        batch = data.next_batch()
        try:
            state, metrics = step_fn(state, batch)
        except Exception:
            # preemption / transient failure: restore and retry
            if mgr.latest_step() is None:
                raise
            state, extra, at = mgr.restore(state)
            if "data" in extra:
                data.load_state_dict(extra["data"])
            print(f"[train] step failed; restored from {at}")
            continue
        s = int(state.step)
        if "on_step" in hooks:
            hooks["on_step"](s, state, metrics)
        if s % log_every == 0 or s == run.steps:
            history["loss"].append(float(metrics["loss"]))
            history["ce"].append(float(metrics["ce"]))
            history["step"].append(s)
            dt = (time.perf_counter() - t0) / max(s, 1)
            print(f"[train] step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step")
        if run.checkpoint_every and s % run.checkpoint_every == 0:
            mgr.save(s, state, {"data": data.state_dict()})
    mgr.wait()
    return state, history
