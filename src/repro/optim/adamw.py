"""AdamW with low-precision moment storage (pure JAX, no optax).

``moment_dtype`` extends the paper's thesis to optimizer state:
  float32  -- exact baseline
  bfloat16 -- 2x moment memory saving
  posit8   -- 4x: moments live as Posit(8,0) codes + per-tensor po2 scale
              ("8-bit Adam"); decode -> update -> re-encode each step.
At trillion-parameter scale (kimi-k2 on 512 chips) this is the difference
between fitting HBM or not -- see EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import codec as codec_mod
from ..core import formats as fmt

__all__ = ["OptConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"   # float32 | bfloat16 | posit8


_BLOCK = 256  # blockwise quantization granularity (bitsandbytes-style)


def _q_state(x: jax.Array, moment_dtype: str, sqrt_domain: bool = False):
    """Quantize a moment tensor.

    posit8 uses BLOCKWISE power-of-two scales (per 256 elements): a single
    per-tensor scale zeroes most of Adam's second moment (its dynamic
    range vastly exceeds posit8's 2^+-6), which sends 1/sqrt(v) steps to
    infinity -- observed, then fixed here.  ``sqrt_domain`` stores
    sqrt(v) instead of v, halving the needed dynamic range again.
    """
    if moment_dtype == "float32":
        return x
    if moment_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if sqrt_domain:
        x = jnp.sqrt(x)
    last = x.shape[-1] if x.ndim else 1
    if x.ndim and last % _BLOCK == 0:
        # codes KEEP THE PARAM'S SHAPE so the path-based sharding rules
        # shard moment codes exactly like their parameter; a flat
        # (N/256, 256) layout is unshardable and replicated terabytes at
        # kimi-k2 scale (observed before this fix).
        blocks = x.reshape(x.shape[:-1] + (last // _BLOCK, _BLOCK))
        s = jnp.max(jnp.abs(blocks), axis=-1) / 64.0 + 1e-30
        s = jnp.exp2(jnp.ceil(jnp.log2(s)))
        codes = codec_mod.encode(
            fmt.POSIT8, (blocks / s[..., None]).astype(jnp.float32))
        return {"codes": codes.reshape(x.shape).astype(jnp.int8),
                "blk_scale": s.astype(jnp.float32)}
    # small / odd-shaped tensors: per-tensor scale
    s = jnp.max(jnp.abs(x)) / 64.0 + 1e-30
    s = jnp.exp2(jnp.ceil(jnp.log2(s)))
    codes = codec_mod.encode(fmt.POSIT8, (x / s).astype(jnp.float32))
    return {"codes": codes.astype(jnp.int8),
            "blk_scale": s.astype(jnp.float32)}


def _dq_state(x, moment_dtype: str, shape=None,
              sqrt_domain: bool = False) -> jax.Array:
    if moment_dtype == "float32":
        return x
    if moment_dtype == "bfloat16":
        return x.astype(jnp.float32)
    codes = x["codes"].astype(jnp.int32)
    s = x["blk_scale"]
    vals = codec_mod.decode(fmt.POSIT8, codes)
    if s.ndim:
        blocks = vals.reshape(vals.shape[:-1] + (s.shape[-1], _BLOCK))
        out = (blocks * s[..., None]).reshape(vals.shape)
    else:
        out = vals * s
    if sqrt_domain:
        out = jnp.square(out)
    return out


def adamw_init(params, cfg: OptConfig):
    def zero_like(sqrt_domain):
        def f(p):
            z = jnp.zeros_like(p, dtype=jnp.float32)
            return _q_state(z, cfg.moment_dtype, sqrt_domain)
        return f
    return {
        "m": jax.tree.map(zero_like(False), params),
        "v": jax.tree.map(zero_like(True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr, cfg: OptConfig):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    is_q = cfg.moment_dtype == "posit8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_f = _dq_state(m, cfg.moment_dtype, p.shape)
        v_f = _dq_state(v, cfg.moment_dtype, p.shape, sqrt_domain=True)
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, _q_state(m_new, cfg.moment_dtype), \
            _q_state(v_new, cfg.moment_dtype, sqrt_domain=True)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    if is_q:
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    else:
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
