"""LR schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, base_lr * cos)


def constant(step, base_lr: float):
    return base_lr
