from .adamw import adamw_init, adamw_update, OptConfig  # noqa: F401
from .schedules import warmup_cosine  # noqa: F401
