"""Continuous-batching scheduler: FIFO admission gated on free pages,
LIFO preemption, retire-on-EOS.

The scheduler owns the REQUEST state machine and the page accounting;
it never touches the model.  The engine drives it:

  submit()          WAITING, queued FIFO.
  admit()           WAITING -> PREFILLING while a batch slot is open and
                    the pool's UNCLAIMED free pages can cover the
                    request's whole prefix plus one decode slot.  Strict
                    FIFO: a too-big head blocks the queue
                    (deterministic, no starvation).  Pages are NOT
                    allocated here -- they are claimed lazily, chunk by
                    chunk, as the engine prefills
                    (``ensure_prefill_capacity``); the claim accounting
                    keeps co-admitted requests from fighting over the
                    same free pages.
  ensure_prefill_capacity()
                    called before each prefill chunk: allocates the
                    pages the chunk's slots land in, preempting younger
                    requests if the pool is dry.  PREFILLING -> RUNNING
                    via ``prefill_complete`` once the engine has paged
                    the whole prefix and sampled the first token.
  ensure_capacity() called before every decode step for each running
                    request: allocates the next page when the request's
                    position crosses a page boundary.  On pool
                    exhaustion the YOUNGEST request is preempted (its
                    pages freed, its request re-queued at the FRONT) --
                    a RUNNING victim loses no tokens (its prefix
                    re-prefills on re-admission and greedy decoding
                    resumes exactly where it stopped); a PREFILLING
                    victim restarts its prefill from chunk 0.
  retire()          RUNNING -> FINISHED (EOS hit or token budget spent);
                    pages return to the pool the same step.

ORDERING CONTRACT: the engine must run ``ensure_capacity`` for the
already-running batch BEFORE ``admit``.  The PR 3 engine admitted (and
fully prefilled) newcomers first; under pool pressure the newcomer took
the last free page, ``ensure_capacity`` then preempted it as the
youngest victim, and its entire prefill was thrown away -- every step,
for as long as the pressure lasted.  ``wasted_prefill_tokens`` counts
the prefill work preemption discards, so that regression is measurable.

PREFIX CACHING (``Scheduler(prefix_cache=True)``): whole prompt-prefix
pages of completed prefills are registered in a page-aligned
``PrefixIndex`` and SHARED with later requests whose prompt starts with
the same token blocks (XR traffic repeats the same scene/system
preamble ahead of every query).  On admission the queue head's prompt
is matched block by block against the index; matched pages attach to
the request read-only (``PagedKVPool.incref``) and its chunk cursor
starts past them, so admission budgets -- and prefill computes -- only
the NEW pages the request still needs.  Retiring decrefs shared pages
back to the index's own reference; when the free list runs dry,
unreferenced cached pages are evicted LRU (leaf-first along the prefix
chains) BEFORE any request is preempted.  See ``serve/paged_kv.py`` for
the refcount / copy-on-write contract that keeps shared pages
read-only.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..obs import MetricRegistry, NULL_RECORDER, bind_counters
from .paged_kv import PagedKVPool

__all__ = ["Request", "Scheduler", "PrefixIndex", "DecodeRunner",
           "WAITING", "PREFILLING", "RUNNING", "FINISHED"]

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its paged-cache bookkeeping."""

    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    status: str = WAITING
    pages: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    next_token: int = -1                # fed to the next decode step
    preemptions: int = 0
    prefilled: int = 0                  # chunk cursor: prefix tokens paged in
    cached_tokens: int = 0              # leading tokens served by shared pages
    slab: Optional[int] = None          # state-slab id (recurrent families)
    # preemption snapshot of a stateful request: the exported quantized
    # state (+ KV pages for hybrids).  Resume imports it and continues
    # decoding EXACTLY -- no re-prefill, nothing recomputed.
    resume: Optional[Dict] = None

    @property
    def prefix(self) -> np.ndarray:
        """Tokens whose KV must be live: prompt + generated so far."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def position(self) -> int:
        """Cache slot the next decode step writes (== the position the
        last generated token's KV lands at)."""
        return len(self.prompt) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def output(self) -> np.ndarray:
        return self.prefix


@dataclasses.dataclass
class _PrefixEntry:
    """One cached whole-page prompt block: its pool page, its parent
    digest in the prefix chain, the EXACT tokens of its block (the
    digest-collision guard), its chain depth (in blocks) and how many
    cached children extend it (eviction is leaf-first)."""

    page: int
    parent: Optional[int]
    block: Tuple[int, ...]
    depth: int
    children: int = 0


class PrefixIndex:
    """Page-aligned prefix cache: whole-page prompt token blocks ->
    shared pool pages, with LRU leaf-first eviction.

    Keys are a DIGEST CHAIN ``key_i = hash((key_{i-1}, block_i))`` (the
    first block's parent is ``None``), so walking a prompt costs O(page)
    per block instead of re-hashing the whole nested prefix at every
    depth.  A digest is never trusted alone: every entry stores its
    exact ``(parent, block)`` and a lookup verifies both, so a hash
    collision degrades to a cache MISS (or an uncacheable block on
    insert), never to attaching the wrong pages.  The index holds its
    OWN reference on every cached page (``pool.incref`` on insert,
    ``pool.free`` on evict); a cached page at refcount 1 is referenced
    by nobody but the cache and is the only kind eviction may take.
    Eviction is leaf-first along the chains so a surviving entry is
    always reachable by a future lookup (evicting a middle block would
    strand its cached descendants as dead weight).

    ``hits``/``hit_tokens`` count per ADMISSION: a preempted sharer
    that re-hits its cached prefix on resume counts again, because its
    re-prefill is skipped again (the scheduler's
    ``wasted_prefill_tokens`` likewise never charges cached tokens).
    """

    # every public run counter; ``reset_counters`` derives from this
    # registry, so adding a counter here is the WHOLE change
    _COUNTERS = ("hits",          # admissions served by cached pages
                 "hit_tokens",    # prefill tokens served cached
                 "misses",        # prefix-enabled admissions with no match
                 "evictions")

    def __init__(self, pool: PagedKVPool,
                 registry: Optional[MetricRegistry] = None,
                 namespace: str = "prefix"):
        self.pool = pool
        self._entries: "OrderedDict[int, _PrefixEntry]" = OrderedDict()
        self.metrics = registry if registry is not None else MetricRegistry()
        bind_counters(self, self.metrics, namespace)
        self.metrics.gauge(
            f"{namespace}/hit_rate",
            fn=lambda: self.hits / max(self.hits + self.misses, 1))

    def reset_counters(self) -> None:
        for c in self._COUNTERS:
            setattr(self, c, 0)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def cached_pages(self) -> List[int]:
        return [e.page for e in self._entries.values()]

    @staticmethod
    def _blocks(prompt: np.ndarray, psize: int, n: int):
        """The first ``n`` whole-page token blocks of ``prompt`` as the
        digest-chain walk ``(key, parent_key, block_tokens, index)``."""
        key = None
        for i in range(n):
            blk = tuple(int(t) for t in prompt[i * psize:(i + 1) * psize])
            parent, key = key, hash((key, blk))
            yield key, parent, blk, i

    def _lookup(self, key: int, parent: Optional[int],
                blk: Tuple[int, ...]) -> Optional[_PrefixEntry]:
        """The entry for this exact (parent, block) pair, or None --
        a digest hit with mismatched contents is a collision, not a
        match."""
        entry = self._entries.get(key)
        if entry is not None and entry.parent == parent \
                and entry.block == blk:
            return entry
        return None

    def match(self, prompt: np.ndarray) -> List[int]:
        """Keys of the longest cached chain of whole prompt pages.  The
        match is CAPPED at the page strictly before the one holding the
        prompt's last token, so a hit request always recomputes at least
        one prompt token -- the logits that sample its first output (and
        the page its first decode write may land in stays private)."""
        psize = self.pool.page_size
        keys = []
        for key, parent, blk, _ in self._blocks(
                prompt, psize, (len(prompt) - 1) // psize):
            if self._lookup(key, parent, blk) is None:
                break
            keys.append(key)
        return keys

    def acquire(self, prompt: np.ndarray) -> List[int]:
        """Attach the matched prefix: one new reference per shared page
        (the caller's), entries bumped to MRU.  Returns the pages in
        logical block order; release by ``pool.free`` (decref)."""
        keys = self.match(prompt)
        pages = [self._entries[k].page for k in keys]
        self.pool.incref(pages)
        for k in keys:
            self._entries.move_to_end(k)
        return pages

    def insert(self, prompt: np.ndarray, pages: List[int]) -> None:
        """Register every whole prompt page of a completed prefill.
        Blocks already cached (including the request's own attached
        shared pages) are bumped to MRU, not duplicated -- when two
        requests with the same preamble prefill concurrently, the first
        insertion wins and the loser's private copy simply retires with
        it.  A digest collision (the slot holds a DIFFERENT block) ends
        the chain: that prefix is uncacheable, never mis-cached."""
        psize = self.pool.page_size
        for key, parent, blk, i in self._blocks(prompt, psize,
                                                len(prompt) // psize):
            entry = self._entries.get(key)
            if entry is None:
                self.pool.incref([pages[i]])
                self._entries[key] = _PrefixEntry(pages[i], parent, blk,
                                                  i + 1)
                if parent is not None:
                    self._entries[parent].children += 1
            elif entry.parent != parent or entry.block != blk:
                break
            self._entries.move_to_end(key)

    def evict(self, n: int) -> int:
        """Free up to ``n`` cached pages nobody references (refcount 1,
        the index's own), LRU order among current LEAVES of the prefix
        chains.  Returns how many pages went back to the free list."""
        freed = 0
        while freed < n:
            victim = next(
                (key for key, e in self._entries.items()
                 if e.children == 0 and self.pool.refcount(e.page) == 1),
                None)
            if victim is None:
                break
            entry = self._entries.pop(victim)
            if entry.parent is not None:
                self._entries[entry.parent].children -= 1
            self.pool.free([entry.page])
            self.evictions += 1
            freed += 1
        return freed

    def reclaimable_pages(self) -> int:
        """How many cached pages eviction COULD hand back right now: a
        page is reclaimable iff nothing but the cache references it and
        every cached child is itself reclaimable (leaf-first eviction
        can only reach a parent once its subtree is gone)."""
        blocked = {key: 0 for key in self._entries}
        n = 0
        for key in sorted(self._entries,
                          key=lambda k: -self._entries[k].depth):
            e = self._entries[key]
            if self.pool.refcount(e.page) == 1 and blocked[key] == 0:
                n += 1
            elif e.parent is not None:
                blocked[e.parent] += 1
        return n


class Scheduler:
    """FIFO admission + LIFO preemption over a shared ``PagedKVPool``."""

    # public run counters; ``reset_counters`` derives from this registry
    _COUNTERS = ("preemption_count",
                 "prefill_preemptions",   # victims dropped mid-prefill
                 "wasted_prefill_tokens")  # prefix KV tossed by preemption

    def __init__(self, pool: PagedKVPool, max_batch: int,
                 max_pages_per_req: Optional[int] = None,
                 prefix_cache: bool = False,
                 registry: Optional[MetricRegistry] = None,
                 trace=None,
                 namespace: str = "scheduler"):
        self.pool = pool
        self.max_batch = int(max_batch)
        # widest page-table row the engine's fixed-shape decode step can
        # build; None = unbounded (pool capacity is the only limit)
        self.max_pages_per_req = max_pages_per_req
        # telemetry: counters live on a MetricRegistry (a private one
        # when the scheduler is used standalone); lifecycle transitions
        # are announced on the trace recorder (no-op unless enabled)
        self.metrics = registry if registry is not None else MetricRegistry()
        self._trace = trace if trace is not None else NULL_RECORDER
        self.prefix = PrefixIndex(pool, registry=self.metrics,
                                  namespace=f"{namespace}/prefix") \
            if prefix_cache else None
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        bind_counters(self, self.metrics, namespace)
        self.preempted_log: List[int] = []    # rids, in preemption order
        self.retired_log: List[int] = []      # rids, in retirement order
        # batch epoch: bumped on every transition that can change any
        # request's page-table row (admission, prefill completion, page
        # growth, preemption, retirement).  The engine keys its device-
        # resident page-table upload on this: an unchanged epoch + an
        # unchanged running set means every row is bit-identical, so the
        # decode dispatch re-uses the resident (B, NP) table instead of
        # rebuilding + re-uploading it.  Bumping liberally is safe (one
        # redundant small upload); missing a bump would corrupt decode,
        # so every pages-touching mutation above bumps it.
        self.epoch = 0

    def reset_counters(self) -> None:
        """Zero the run counters and logs (bench warm-up hygiene); the
        prefix index's counters reset with them."""
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.preempted_log.clear()
        self.retired_log.clear()
        if self.prefix is not None:
            self.prefix.reset_counters()

    # -- queue --------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0 and max_new_tokens >= 1
        total = prompt.size + int(max_new_tokens)
        need = self.pool.pages_for(total)
        if need > self.pool.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.pool.n_pages}: raise n_pages or shorten the request")
        if self.max_pages_per_req is not None \
                and need > self.max_pages_per_req:
            # the same rejection the engine gives: a page list longer
            # than the fixed (B, NP) page-table row of the batched
            # decode step can never be served, however big the pool is
            raise ValueError(
                f"prompt+new = {total} exceeds max_len="
                f"{self.max_pages_per_req * self.pool.page_size} "
                f"({need} pages > the {self.max_pages_per_req}-page "
                f"table row of the engine's decode step)")
        # the fixed part of the footprint: a recurrent/hybrid request
        # needs one state slab for its whole lifetime, so a pool with
        # none can never serve it (pages alone don't cover the family)
        if self.pool.has_state and self.pool.n_slabs < 1:
            raise ValueError(
                f"family {self.pool.cfg.family!r} keeps per-request "
                f"recurrent state, but the pool has n_slabs=0: size the "
                f"pool with at least one state slab")
        req = Request(self._next_rid, prompt, int(max_new_tokens), eos_id)
        self._next_rid += 1
        self.waiting.append(req)
        self._trace.event("SUBMIT", rid=req.rid,
                          prompt_tokens=int(prompt.size),
                          max_new_tokens=int(max_new_tokens))
        return req.rid

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission ----------------------------------------------------------

    def _admission_budget(self) -> int:
        """Pages admission may promise: free pages, plus what prefix-
        cache eviction could reclaim, minus the outstanding claims of
        already-admitted PREFILLING requests (their full need minus what
        they have allocated OR attached shared) -- co-admitted prefills
        must never race each other to the same pages."""
        budget = self.pool.free_pages
        if self.prefix is not None:
            budget += self.prefix.reclaimable_pages()
        for r in self.running:
            if r.status == PREFILLING:
                claim = self.pool.pages_for(len(r.prefix) + 1) - len(r.pages)
                budget -= max(claim, 0)
        return budget

    def admit(self) -> List[Request]:
        """Move FIFO-head requests to PREFILLING while a batch slot is
        open and the admission budget covers the NEW pages the head
        still needs: under prefix caching the head's prompt is matched
        against the index first, the cached prefix pages attach to it
        read-only, and only the remainder is budgeted (and later
        computed -- the chunk cursor starts past the match)."""
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            head = self.waiting[0]
            if head.resume is not None:
                # preemption snapshot: import the exported state (+ KV
                # pages) and go straight back to RUNNING -- the exact
                # form of resume, nothing to re-prefill
                if not self._admit_resume(head):
                    break                # strict FIFO: head blocks
                self.waiting.popleft()
                self.running.append(head)
                admitted.append(head)
                self._trace.event("RESUME", rid=head.rid,
                                  generated=len(head.generated))
                continue
            # constant-footprint admission: a stateful head needs its
            # ONE slab available now (co-admitted requests hold theirs
            # already, so free_slabs is the whole claim accounting)
            if self.pool.has_state and self.pool.free_slabs < 1:
                break
            shared = self.prefix.acquire(head.prompt) \
                if self.prefix is not None else []
            # budget AFTER the attach: the shared pages are pinned at
            # refcount >= 2 now, so reclaimable_pages no longer counts
            # them, and prior same-call admissions show up as claims
            need = self.pool.pages_for(len(head.prefix) + 1) - len(shared)
            if need > self._admission_budget():
                if shared:
                    self.pool.free(shared)   # detach: head stays queued
                break                    # head-of-line blocks: strict FIFO
            self.waiting.popleft()
            head.status = PREFILLING
            head.pages = list(shared)
            if self.pool.has_state:
                head.slab = self.pool.alloc_slab()
            head.cached_tokens = len(shared) * self.pool.page_size
            head.prefilled = head.cached_tokens
            if shared:
                self.prefix.hits += 1
                self.prefix.hit_tokens += head.cached_tokens
            elif self.prefix is not None:
                self.prefix.misses += 1
            self.running.append(head)
            admitted.append(head)
            self._trace.event("ADMIT", rid=head.rid,
                              cached_tokens=head.cached_tokens)
        if admitted:
            self.epoch += 1
        return admitted

    def _admit_resume(self, head: Request) -> bool:
        """Import a preemption snapshot: allocate the pages + slab it
        needs, scatter the payload back in, RUNNING.  False (no state
        changed) if the pool cannot host it yet."""
        snap = head.resume
        kv = snap.get("kv")
        n = int(kv["k_codes"].shape[1]) if kv is not None else 0
        if n > self._admission_budget():
            return False
        if self.pool.has_state and self.pool.free_slabs < 1:
            return False
        pages: List[int] = []
        if n:
            if self.prefix is not None and self.pool.free_pages < n:
                self.prefix.evict(n - self.pool.free_pages)
            got = self.pool.alloc(n)
            if got is None:
                return False
            pages = got
        slab = None
        if self.pool.has_state:
            slab = self.pool.alloc_slab()
        if kv is not None:
            self.pool.import_pages(kv, pages)
        if "state" in snap:
            self.pool.import_state(snap["state"], slab)
        head.pages = pages
        head.slab = slab
        head.resume = None
        head.status = RUNNING
        return True

    def prefill_complete(self, req: Request) -> None:
        """PREFILLING -> RUNNING: the whole prefix is paged in and the
        engine has sampled the request's next token.  Under prefix
        caching this is also the publication point: the request's whole
        prompt pages register in the index and become shareable."""
        assert req.status == PREFILLING, req.status
        req.status = RUNNING
        self.epoch += 1
        if self.prefix is not None:
            self.prefix.insert(req.prompt, req.pages)
        # the first output token samples from the prefill logits, so
        # this event is the request's time-to-first-token stamp
        self._trace.event("PREFILL_COMPLETE", rid=req.rid,
                          prompt_tokens=len(req.prompt),
                          cached_tokens=req.cached_tokens)

    # -- capacity / preemption ----------------------------------------------

    def _grow(self, req: Request, need_pages: int) -> bool:
        """Grow ``req``'s page list to ``need_pages``: free list first,
        then LRU eviction of unreferenced prefix-cache pages, and only
        when the cache is bone-dry preempt the youngest request.  False
        if ``req`` itself was preempted (it is no longer running)."""
        grew = False
        while need_pages > len(req.pages):
            got = self.pool.alloc(1)
            if got is not None:
                req.pages.extend(got)
                grew = True
                continue
            if self.prefix is not None and self.prefix.evict(1):
                continue
            victim = self.running[-1]    # youngest admitted
            self.preempt(victim)
            if victim is req:
                return False
        if grew:
            self.epoch += 1
        return True

    def ensure_capacity(self, req: Request, horizon: int = 1) -> bool:
        """Make sure ``req`` owns every page the next ``horizon`` decode
        writes land in (slots ``position .. position+horizon-1``) --
        the multi-step decode dispatch pre-claims its whole window up
        front, so no page can be missing mid-scan (``horizon=1`` is the
        single-step behavior).  False if ``req`` itself was preempted.

        Pure-recurrent families return True unconditionally: the
        request's footprint is its one slab, already allocated at
        admission -- decode NEVER grows it, whatever the horizon."""
        if not self.pool.has_kv:
            return True
        last = req.position + max(int(horizon), 1) - 1
        return self._grow(req, last // self.pool.page_size + 1)

    def ensure_prefill_capacity(self, req: Request, upto: int) -> bool:
        """Make sure ``req`` owns every page for prefix slots
        [0, upto) -- called per chunk (lazy page alloc).  False if
        ``req`` itself was preempted."""
        return self._grow(req, self.pool.pages_for(upto))

    def preempt(self, req: Request) -> None:
        """Free the victim's device resources and put it back at the
        FRONT of the queue.  A RUNNING attention-only victim keeps its
        generated tokens (resume = re-prefill prefix); a PREFILLING
        victim restarts from chunk 0.  A RUNNING STATEFUL victim is
        snapshotted instead: its quantized state (+ KV pages for
        hybrids) exports to a host-held payload that resume imports
        bitwise -- nothing is recomputed, so nothing is charged to
        ``wasted_prefill_tokens`` (state snapshot/restore replaces
        re-prefill-from-prefix exactly)."""
        assert req.status in (RUNNING, PREFILLING), req.status
        self._trace.event("PREEMPT", rid=req.rid, was=req.status)
        snapshot = self.pool.has_state and req.status == RUNNING
        # tokens served off shared cached pages were never computed by
        # this request, so preemption does not waste them -- and the
        # pages themselves survive in the index (the decref below drops
        # only the request's reference), ready to re-hit on resume
        if req.status == PREFILLING:
            self.prefill_preemptions += 1
            self.wasted_prefill_tokens += max(
                req.prefilled - req.cached_tokens, 0)
        elif snapshot:
            snap: Dict = {"state": self.pool.export_state(req.slab)}
            if req.pages:
                snap["kv"] = self.pool.export_pages(req.pages)
            req.resume = snap
        else:
            self.wasted_prefill_tokens += max(
                req.position + 1 - req.cached_tokens, 0)
        self.pool.free(req.pages)
        req.pages = []
        if req.slab is not None:
            self.pool.free_slab(req.slab)
            req.slab = None
        if not snapshot:
            req.prefilled = 0
            req.cached_tokens = 0
            req.next_token = -1
        req.status = WAITING
        req.preemptions += 1
        self.preemption_count += 1
        self.preempted_log.append(req.rid)
        self.running.remove(req)
        self.waiting.appendleft(req)
        self.epoch += 1

    def reaccept(self, req: Request) -> None:
        """Queue-front re-entry of a request BOUNCED back from a decode
        runner (disaggregated serving) -- the twin of :meth:`preempt`
        for a victim whose pages lived in the DECODE pool: the runner
        already freed them (``DecodeRunner.bounce``), so only the queue
        and waste accounting happen here.  The request keeps its
        generated tokens and re-prefills prompt+generated on
        re-admission, exactly like a RUNNING preemption victim."""
        assert req.status == WAITING and not req.pages, \
            (req.status, req.pages)
        # its whole prefix KV (computed on the prefill side, shipped
        # across the handoff) is gone; cached_tokens was reset by the
        # bounce, so the full prefix counts as wasted -- matching what
        # a RUNNING-victim preempt charges.  A stateful bounce carries
        # a snapshot instead: resume is exact, nothing is wasted.
        if req.resume is None:
            self.wasted_prefill_tokens += req.position + 1
        req.preemptions += 1
        self.preemption_count += 1
        self.preempted_log.append(req.rid)
        self.waiting.appendleft(req)

    # -- retirement ---------------------------------------------------------

    def retire(self, req: Request) -> None:
        """RUNNING -> FINISHED.  ``free`` is a decref: the request's
        private pages return to the pool, while its prompt-prefix pages
        -- published by ``prefill_complete`` -- stay cached under the
        prefix index's own reference, shareable until evicted."""
        assert req.status == RUNNING
        self.pool.free(req.pages)
        req.pages = []
        if req.slab is not None:
            self.pool.free_slab(req.slab)
            req.slab = None
        req.status = FINISHED
        self.running.remove(req)
        self.finished[req.rid] = req
        self.retired_log.append(req.rid)
        self.epoch += 1
        self._trace.event("RETIRE", rid=req.rid,
                          generated=len(req.generated))

    # -- page handoff (disaggregated serving) -------------------------------

    def release(self, req: Request) -> None:
        """Prefill-side endpoint of a page handoff: the request's prefix
        pages have been EXPORTED (``PagedKVPool.export_pages``), so drop
        this side's references and remove the request from the running
        set -- it stays RUNNING, but on the decode side now.  Under
        prefix caching the prompt-prefix pages published by
        ``prefill_complete`` survive in the index under its own
        reference, shareable by later arrivals exactly as if the
        request had retired here."""
        assert req.status == RUNNING, req.status
        self.pool.free(req.pages)
        req.pages = []
        if req.slab is not None:
            self.pool.free_slab(req.slab)
            req.slab = None
        self.running.remove(req)
        self.epoch += 1


class DecodeRunner:
    """The DECODE-side scheduler half of disaggregated serving
    (``serve/disagg.py``): owns the decode pool's accounting for
    RUNNING requests only -- K-step horizon claims, retirement on
    EOS/budget, and the decode-side mapping epoch (the same epoch
    protocol the interleaved engine keys its page-table cache on, so
    uploads stay cached across handoffs).

    Admission, chunk budgeting, prefix caching and mid-prefill
    preemption all live on the prefill-side admitter (a plain
    ``Scheduler``); a request only ever arrives here through an accepted
    page handoff, already RUNNING with its first token sampled.  When
    the decode pool runs dry mid-growth the YOUNGEST accepted request is
    BOUNCED -- its decode pages freed, the request queued on ``bounced``
    for the engine to hand back to the admitter (``Scheduler.reaccept``)
    where it re-prefills prompt+generated -- the disaggregated analogue
    of LIFO preemption, with the same youngest-victim-first progress
    guarantee (``submit`` caps a request's total need at the decode
    pool, so a lone request always fits and bouncing always frees pages
    held by someone younger than the oldest)."""

    _COUNTERS = ("bounce_count",)

    def __init__(self, pool: PagedKVPool, max_batch: int,
                 registry: Optional[MetricRegistry] = None,
                 trace=None,
                 namespace: str = "runner"):
        self.pool = pool
        self.max_batch = int(max_batch)
        self.running: List[Request] = []      # acceptance order
        self.finished: Dict[int, Request] = {}
        self.bounced: List[Request] = []      # drained by the engine
        self.retired_log: List[int] = []
        self.metrics = registry if registry is not None else MetricRegistry()
        self._trace = trace if trace is not None else NULL_RECORDER
        bind_counters(self, self.metrics, namespace)
        self.epoch = 0

    def reset_counters(self) -> None:
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.retired_log.clear()

    @property
    def has_slot(self) -> bool:
        return len(self.running) < self.max_batch

    def accept(self, req: Request, pages: List[int],
               slab: Optional[int] = None) -> None:
        """Take ownership of a handed-off request: its payload has been
        imported into this pool's ``pages`` (and state ``slab`` for
        recurrent families), which become its page-table row here.
        Bumps the epoch -- a new row order means the resident page
        table is stale."""
        assert self.has_slot and req.status == RUNNING, req.status
        req.pages = list(pages)
        req.slab = slab
        self.running.append(req)
        self.epoch += 1

    def ensure_capacity(self, req: Request, horizon: int = 1) -> bool:
        """Decode-side twin of ``Scheduler.ensure_capacity``: own every
        page the next ``horizon`` decode writes land in, bouncing the
        youngest accepted request when the pool is dry.  False if
        ``req`` itself was bounced.  Pure-recurrent: always True --
        the slab accepted with the handoff is the whole footprint."""
        if not self.pool.has_kv:
            return True
        last = req.position + max(int(horizon), 1) - 1
        need = last // self.pool.page_size + 1
        grew = False
        while need > len(req.pages):
            got = self.pool.alloc(1)
            if got is not None:
                req.pages.extend(got)
                grew = True
                continue
            victim = self.running[-1]         # youngest accepted
            self.bounce(victim)
            if victim is req:
                return False
        if grew:
            self.epoch += 1
        return True

    def bounce(self, req: Request) -> None:
        """Evict a running request from the decode side: free its decode
        pages and reset its prefill cursor so the admitter re-prefills
        prompt+generated from chunk 0 (the generated tokens survive --
        greedy decoding resumes where it stopped, like any RUNNING
        preemption victim).  A STATEFUL request snapshots instead (the
        same exact-resume payload ``Scheduler.preempt`` builds): the
        prefill side pushes it back across the channel untouched, no
        re-prefill.  The engine drains ``bounced`` back to the prefill
        admitter's queue front."""
        assert req.status == RUNNING, req.status
        if self.pool.has_state:
            snap: Dict = {"state": self.pool.export_state(req.slab)}
            if req.pages:
                snap["kv"] = self.pool.export_pages(req.pages)
            req.resume = snap
        else:
            req.next_token = -1
            req.prefilled = 0
            req.cached_tokens = 0
        self.pool.free(req.pages)
        req.pages = []
        if req.slab is not None:
            self.pool.free_slab(req.slab)
            req.slab = None
        req.status = WAITING
        self.bounce_count += 1
        self.running.remove(req)
        self.bounced.append(req)
        self.epoch += 1
        self._trace.event("BOUNCE", rid=req.rid,
                          generated=len(req.generated))

    def drain_bounced(self) -> List[Request]:
        out, self.bounced = self.bounced, []
        return out

    def retire(self, req: Request) -> None:
        """RUNNING -> FINISHED on the decode side; pages and slab
        return to the decode pool the same step."""
        assert req.status == RUNNING, req.status
        self.pool.free(req.pages)
        req.pages = []
        if req.slab is not None:
            self.pool.free_slab(req.slab)
            req.slab = None
        req.status = FINISHED
        self.running.remove(req)
        self.finished[req.rid] = req
        self.retired_log.append(req.rid)
        self.epoch += 1
        self._trace.event("RETIRE", rid=req.rid,
                          generated=len(req.generated))
