"""Continuous-batching scheduler: FIFO admission gated on free pages,
LIFO preemption, retire-on-EOS.

The scheduler owns the REQUEST state machine and the page accounting;
it never touches the model.  The engine drives it:

  submit()          WAITING, queued FIFO.
  admit()           WAITING -> RUNNING while a batch slot is open and the
                    pool can page the request's whole prefix plus one
                    decode slot.  Strict FIFO: a too-big head blocks the
                    queue (deterministic, no starvation).
  ensure_capacity() called before every decode step for each running
                    request: allocates the next page when the request's
                    position crosses a page boundary.  On pool
                    exhaustion the YOUNGEST running request is preempted
                    (its pages freed, its request re-queued at the
                    FRONT) -- the victim loses no tokens: its prefix
                    (prompt + generated so far) re-prefills on
                    re-admission and greedy decoding resumes exactly
                    where it stopped.
  retire()          RUNNING -> FINISHED (EOS hit or token budget spent);
                    pages return to the pool the same step.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .paged_kv import PagedKVPool

__all__ = ["Request", "Scheduler",
           "WAITING", "RUNNING", "FINISHED"]

WAITING = "waiting"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its paged-cache bookkeeping."""

    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    status: str = WAITING
    pages: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    next_token: int = -1                # fed to the next decode step
    preemptions: int = 0

    @property
    def prefix(self) -> np.ndarray:
        """Tokens whose KV must be live: prompt + generated so far."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def position(self) -> int:
        """Cache slot the next decode step writes (== the position the
        last generated token's KV lands at)."""
        return len(self.prompt) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def output(self) -> np.ndarray:
        return self.prefix


class Scheduler:
    """FIFO admission + LIFO preemption over a shared ``PagedKVPool``."""

    def __init__(self, pool: PagedKVPool, max_batch: int):
        self.pool = pool
        self.max_batch = int(max_batch)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self.preemption_count = 0

    # -- queue --------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0 and max_new_tokens >= 1
        need = self.pool.pages_for(prompt.size + max_new_tokens)
        if need > self.pool.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.pool.n_pages}: raise n_pages or shorten the request")
        req = Request(self._next_rid, prompt, int(max_new_tokens), eos_id)
        self._next_rid += 1
        self.waiting.append(req)
        return req.rid

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission ----------------------------------------------------------

    def admit(self) -> List[Request]:
        """Move FIFO-head requests to RUNNING while a batch slot is open
        and the pool can page prefix + 1 decode slot.  Pages are
        allocated here; the engine prefills the returned requests."""
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            head = self.waiting[0]
            need = self.pool.pages_for(len(head.prefix) + 1)
            pages = self.pool.alloc(need)
            if pages is None:
                break                    # head-of-line blocks: strict FIFO
            self.waiting.popleft()
            head.pages = pages
            head.status = RUNNING
            self.running.append(head)
            admitted.append(head)
        return admitted

    # -- capacity / preemption ----------------------------------------------

    def ensure_capacity(self, req: Request) -> bool:
        """Make sure ``req`` owns the page its next write lands in,
        preempting younger requests if the pool is dry.  False if ``req``
        itself was preempted (it is no longer running)."""
        need_idx = req.position // self.pool.page_size
        while need_idx >= len(req.pages):
            got = self.pool.alloc(1)
            if got is not None:
                req.pages.extend(got)
                continue
            victim = self.running[-1]    # youngest admitted
            self.preempt(victim)
            if victim is req:
                return False
        return True

    def preempt(self, req: Request) -> None:
        """Free the victim's pages and put it back at the FRONT of the
        queue; its generated tokens stay (resume = re-prefill prefix)."""
        assert req.status == RUNNING
        self.pool.free(req.pages)
        req.pages = []
        req.status = WAITING
        req.next_token = -1
        req.preemptions += 1
        self.preemption_count += 1
        self.running.remove(req)
        self.waiting.appendleft(req)

    # -- retirement ---------------------------------------------------------

    def retire(self, req: Request) -> None:
        assert req.status == RUNNING
        self.pool.free(req.pages)
        req.pages = []
        req.status = FINISHED
        self.running.remove(req)
        self.finished[req.rid] = req
