"""Continuous-batching scheduler: FIFO admission gated on free pages,
LIFO preemption, retire-on-EOS.

The scheduler owns the REQUEST state machine and the page accounting;
it never touches the model.  The engine drives it:

  submit()          WAITING, queued FIFO.
  admit()           WAITING -> PREFILLING while a batch slot is open and
                    the pool's UNCLAIMED free pages can cover the
                    request's whole prefix plus one decode slot.  Strict
                    FIFO: a too-big head blocks the queue
                    (deterministic, no starvation).  Pages are NOT
                    allocated here -- they are claimed lazily, chunk by
                    chunk, as the engine prefills
                    (``ensure_prefill_capacity``); the claim accounting
                    keeps co-admitted requests from fighting over the
                    same free pages.
  ensure_prefill_capacity()
                    called before each prefill chunk: allocates the
                    pages the chunk's slots land in, preempting younger
                    requests if the pool is dry.  PREFILLING -> RUNNING
                    via ``prefill_complete`` once the engine has paged
                    the whole prefix and sampled the first token.
  ensure_capacity() called before every decode step for each running
                    request: allocates the next page when the request's
                    position crosses a page boundary.  On pool
                    exhaustion the YOUNGEST request is preempted (its
                    pages freed, its request re-queued at the FRONT) --
                    a RUNNING victim loses no tokens (its prefix
                    re-prefills on re-admission and greedy decoding
                    resumes exactly where it stopped); a PREFILLING
                    victim restarts its prefill from chunk 0.
  retire()          RUNNING -> FINISHED (EOS hit or token budget spent);
                    pages return to the pool the same step.

ORDERING CONTRACT: the engine must run ``ensure_capacity`` for the
already-running batch BEFORE ``admit``.  The PR 3 engine admitted (and
fully prefilled) newcomers first; under pool pressure the newcomer took
the last free page, ``ensure_capacity`` then preempted it as the
youngest victim, and its entire prefill was thrown away -- every step,
for as long as the pressure lasted.  ``wasted_prefill_tokens`` counts
the prefill work preemption discards, so that regression is measurable.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .paged_kv import PagedKVPool

__all__ = ["Request", "Scheduler",
           "WAITING", "PREFILLING", "RUNNING", "FINISHED"]

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its paged-cache bookkeeping."""

    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    status: str = WAITING
    pages: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    next_token: int = -1                # fed to the next decode step
    preemptions: int = 0
    prefilled: int = 0                  # chunk cursor: prefix tokens paged in

    @property
    def prefix(self) -> np.ndarray:
        """Tokens whose KV must be live: prompt + generated so far."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def position(self) -> int:
        """Cache slot the next decode step writes (== the position the
        last generated token's KV lands at)."""
        return len(self.prompt) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def output(self) -> np.ndarray:
        return self.prefix


class Scheduler:
    """FIFO admission + LIFO preemption over a shared ``PagedKVPool``."""

    def __init__(self, pool: PagedKVPool, max_batch: int):
        self.pool = pool
        self.max_batch = int(max_batch)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self.preemption_count = 0
        self.prefill_preemptions = 0          # victims dropped mid-prefill
        self.wasted_prefill_tokens = 0        # prefix KV tossed by preemption
        self.preempted_log: List[int] = []    # rids, in preemption order

    # -- queue --------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0 and max_new_tokens >= 1
        need = self.pool.pages_for(prompt.size + max_new_tokens)
        if need > self.pool.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.pool.n_pages}: raise n_pages or shorten the request")
        req = Request(self._next_rid, prompt, int(max_new_tokens), eos_id)
        self._next_rid += 1
        self.waiting.append(req)
        return req.rid

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission ----------------------------------------------------------

    def admit(self) -> List[Request]:
        """Move FIFO-head requests to PREFILLING while a batch slot is
        open and the UNCLAIMED free pages cover prefix + 1 decode slot.

        Pages are allocated lazily per chunk, so already-admitted
        PREFILLING requests hold outstanding claims (their full need
        minus what they have allocated); admission budgets against
        free pages minus those claims, keeping co-admitted prefills
        from racing each other to the same pages."""
        budget = self.pool.free_pages
        for r in self.running:
            if r.status == PREFILLING:
                claim = self.pool.pages_for(len(r.prefix) + 1) - len(r.pages)
                budget -= max(claim, 0)
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            head = self.waiting[0]
            need = self.pool.pages_for(len(head.prefix) + 1)
            if need > budget:
                break                    # head-of-line blocks: strict FIFO
            budget -= need
            self.waiting.popleft()
            head.status = PREFILLING
            head.prefilled = 0
            self.running.append(head)
            admitted.append(head)
        return admitted

    def prefill_complete(self, req: Request) -> None:
        """PREFILLING -> RUNNING: the whole prefix is paged in and the
        engine has sampled the request's next token."""
        assert req.status == PREFILLING, req.status
        req.status = RUNNING

    # -- capacity / preemption ----------------------------------------------

    def _grow(self, req: Request, need_pages: int) -> bool:
        """Grow ``req``'s page list to ``need_pages``, preempting the
        youngest request while the pool is dry.  False if ``req`` itself
        was preempted (it is no longer running)."""
        while need_pages > len(req.pages):
            got = self.pool.alloc(1)
            if got is not None:
                req.pages.extend(got)
                continue
            victim = self.running[-1]    # youngest admitted
            self.preempt(victim)
            if victim is req:
                return False
        return True

    def ensure_capacity(self, req: Request) -> bool:
        """Make sure ``req`` owns the page its next decode write lands
        in.  False if ``req`` itself was preempted."""
        return self._grow(req, req.position // self.pool.page_size + 1)

    def ensure_prefill_capacity(self, req: Request, upto: int) -> bool:
        """Make sure ``req`` owns every page for prefix slots
        [0, upto) -- called per chunk (lazy page alloc).  False if
        ``req`` itself was preempted."""
        return self._grow(req, self.pool.pages_for(upto))

    def preempt(self, req: Request) -> None:
        """Free the victim's pages and put it back at the FRONT of the
        queue.  A RUNNING victim keeps its generated tokens (resume =
        re-prefill prefix); a PREFILLING victim restarts from chunk 0."""
        assert req.status in (RUNNING, PREFILLING), req.status
        if req.status == PREFILLING:
            self.prefill_preemptions += 1
            self.wasted_prefill_tokens += req.prefilled
        else:
            self.wasted_prefill_tokens += req.position + 1
        self.pool.free(req.pages)
        req.pages = []
        req.prefilled = 0
        req.status = WAITING
        req.next_token = -1
        req.preemptions += 1
        self.preemption_count += 1
        self.preempted_log.append(req.rid)
        self.running.remove(req)
        self.waiting.appendleft(req)

    # -- retirement ---------------------------------------------------------

    def retire(self, req: Request) -> None:
        assert req.status == RUNNING
        self.pool.free(req.pages)
        req.pages = []
        req.status = FINISHED
        self.running.remove(req)
        self.finished[req.rid] = req
