"""Batched serving engine: prefill -> decode with the packed weight plane.

This is the runtime the decode_* and long_* dry-run shapes lower:
``serve_step`` is one new token against a seq_len KV cache (or SSM state).
Weights can be physically packed (PackedTensor leaves -- HBM holds the
low-bit codes, the paper's memory-bandwidth reduction) and the KV cache
can be Posit(8,0)-quantized end-to-end (``quantized_kv=True``): prefill
returns codes+scales (one-shot ``zoo.quantize_cache`` fused into the
prefill jit, before ``_pad_cache``), decode writes the quantized layout
incrementally and reads only the live prefix of it per step (the
length-aware paths in ``models/attention``) -- the bf16 cache never
exists in HBM.

The engine itself does simple static batching with per-request lengths
masked by position -- enough to serve real batched traffic in the
examples while keeping the step function identical to the dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.policy import PrecisionPolicy
from ..models import zoo

__all__ = ["build_prefill_step", "build_serve_step", "ServeEngine",
           "ContinuousEngine"]


def build_prefill_step(cfg: ModelConfig, last_logit_only: bool = False,
                       quantized_kv: bool = False,
                       kv_group: Optional[int] = None):
    """(params, batch) -> (logits, cache): full-sequence forward that also
    materializes the KV cache / SSM state.

    ``last_logit_only``: return logits only for the final position -- the
    only one generation needs.  XLA pushes the slice up through the
    readout matmul, eliminating ~(S-1)/S of lm_head FLOPs and the
    (B, S, vocab) buffer (a §Perf hillclimb lever for prefill cells).

    ``quantized_kv``: quantize the returned KV cache to posit8 codes +
    ``kv_group``-grouped scales inside the same jit (XLA fuses the
    quantize into the cache write, so the bf16 cache is a transient,
    not an output)."""

    def prefill(params, batch):
        logits, cache, _ = zoo.apply_model(params, batch, cfg, mode="prefill",
                                           cache=None)
        if last_logit_only:
            logits = logits[:, -1:]
        if quantized_kv:
            cache = zoo.quantize_cache(cache, kv_group)
        return logits, cache

    return prefill


def build_serve_step(cfg: ModelConfig, ragged: bool = False):
    """(params, tokens (B,1), cache, pos) -> (logits, new_cache).

    ``ragged=True`` adds a trailing ``pad`` operand ((B,) left-pad
    widths): RoPE positions shift per request and pad cache slots are
    masked, so a left-padded mixed-length batch decodes like its
    unpadded per-request selves."""

    if ragged:
        def serve_step(params, tokens, cache, pos, pad):
            return zoo.decode_model(params, tokens, cfg, cache, pos, pad)
    else:
        def serve_step(params, tokens, cache, pos):
            return zoo.decode_model(params, tokens, cfg, cache, pos)

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Static-batch serving with greedy/temperature sampling."""

    cfg: ModelConfig
    params: Any
    max_len: int = 2048
    # posit8 KV cache end-to-end: prefill returns codes+scales, decode
    # reads only the live prefix of them per step.  The scale grouping
    # follows ``policy.group_size`` (the weight plane's grid).
    quantized_kv: bool = False
    policy: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        self._prefill = jax.jit(build_prefill_step(
            self.cfg, last_logit_only=True,
            quantized_kv=self.quantized_kv, kv_group=kv_group))
        self._step = jax.jit(build_serve_step(self.cfg))
        self._step_ragged = jax.jit(build_serve_step(self.cfg, ragged=True))

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0, key=None,
                 lengths=None) -> np.ndarray:
        """tokens: (B, S0) prompt -> (B, S0+steps) completed.

        ``lengths``: optional (B,) true prompt lengths of a LEFT-padded
        ragged batch (request b occupies ``tokens[b, S0-lengths[b]:]``).
        Pad tokens are masked out of attention and RoPE positions start
        at each request's first real token, so a mixed-length batch
        generates exactly what per-request calls would."""
        b, s0 = tokens.shape
        batch = {"tokens": tokens}
        pad = None
        if lengths is not None:
            if self.cfg.family not in ("dense", "moe") or \
                    self.cfg.rope_kind != "default":
                raise ValueError(
                    "ragged prompts need a pure-attention family with "
                    "default RoPE (SSM state would still absorb pads)")
            lengths = jnp.asarray(lengths, jnp.int32)
            pad = (s0 - lengths).astype(jnp.int32)          # (B,)
            idx = jnp.arange(s0, dtype=jnp.int32)[None]
            batch["positions"] = jnp.maximum(idx - pad[:, None], 0)
            batch["kv_mask"] = idx >= pad[:, None]
        # prefill is unconditional for every model family: it returns the
        # populated KV cache / SSM state (already posit8 codes+scales
        # under quantized_kv) that decode continues from.  Left padding
        # keeps the LAST column the last real token of every request, so
        # the last_logit_only logits feed sampling for ragged batches too.
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, b)
        out = [np.asarray(tokens)]
        last = jnp.argmax(logits, -1).astype(jnp.int32)     # (B, 1)
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(steps):
            out.append(np.asarray(last))
            if pad is None:
                logits, cache = self._step(self.params, last,
                                           cache, jnp.int32(s0 + i))
            else:
                logits, cache = self._step_ragged(
                    self.params, last, cache, jnp.int32(s0 + i), pad)
            lg = logits[:, -1]
            if temperature > 0:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(
                    sub, lg / temperature)[:, None].astype(jnp.int32)
            else:
                last = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)

    # cache leaves with a sequence axis, all laid out (L, B, S, H, ...):
    # bf16 k/v, posit8 codes, and their (..., Gs) scale tensors
    _SEQ_KEYS = frozenset(
        {"k", "v", "k_codes", "v_codes", "k_scale", "v_scale"})

    def _pad_cache(self, cache, b):
        """Grow prefill-length KV buffers to max_len for decode.

        Structure-aware: pads by cache KEY (the seq axis is always axis 2
        of the stacked (L, B, S, H, ...) layout) instead of guessing from
        ndim/shape/dtype -- scale tensors pad on the right rank and SSM /
        RWKV states (no seq axis, no KV keys) pass through untouched."""
        def pad(key, x):
            if key in self._SEQ_KEYS and x.shape[2] < self.max_len:
                pad_width = [(0, 0)] * x.ndim
                pad_width[2] = (0, self.max_len - x.shape[2])
                return jnp.pad(x, pad_width)
            return x

        def rec(node):
            if isinstance(node, dict):
                return {key: (rec(val) if isinstance(val, dict)
                              else pad(key, val))
                        for key, val in node.items()}
            return node

        return rec(cache)


# ---------------------------------------------------------------------------
# Continuous batching over the paged posit8 KV pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousEngine:
    """Continuous-batching serving over a paged posit8 KV pool.

    The static ``ServeEngine`` batches a fixed set of requests against a
    dense ``max_len`` cache: every request pays worst-case KV memory and
    new arrivals wait for the whole batch.  This engine keeps ONE jitted
    decode step of shape ``max_batch`` alive and per step (a) admits
    queued requests (FIFO, gated on free pages; each gets a per-request
    prefill whose quantized cache scatters into its pages), (b) runs one
    batched paged decode for every running request at its OWN position,
    and (c) retires finished requests, returning their pages -- with
    LIFO preemption (free the youngest's pages, requeue it) when the
    pool runs dry.  See ``serve/scheduler.py`` for the policy and
    ``serve/paged_kv.py`` for the page layout.

    The KV plane is ALWAYS the posit8 paged pool (that is the point);
    weights pack per ``policy`` exactly like the static engine.  At
    temperature 0 with ``page_size == default_kv_block(max_len)`` of a
    static engine, outputs match per-request ``ServeEngine.generate``
    token for token (the paged and contiguous block partitions --
    and therefore the online-softmax accumulation order -- coincide).
    """

    cfg: ModelConfig
    params: Any
    n_pages: int = 64
    page_size: Optional[int] = None
    max_batch: int = 8
    max_len: int = 512
    policy: Optional[PrecisionPolicy] = None
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        from ..kernels.flash_decode import default_kv_block
        from .paged_kv import PagedKVPool
        from .scheduler import Scheduler
        if self.cfg.frontend != "none":
            raise ValueError(
                "ContinuousEngine serves token prompts; vision/audio "
                "frontends need per-request frame/patch embeddings the "
                "request queue does not carry")
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        if self.page_size is None:
            self.page_size = default_kv_block(self.max_len)
        assert self.max_len % self.page_size == 0, \
            (self.max_len, self.page_size)
        self.max_pages_per_req = self.max_len // self.page_size
        pool = PagedKVPool(self.cfg, self.n_pages, self.page_size, kv_group)
        self.scheduler = Scheduler(pool, self.max_batch)
        # per-request prefill: FULL logits (the request's last real token
        # sits at len-1 of its page-aligned bucket, not at -1)
        self._prefill = jax.jit(build_prefill_step(
            self.cfg, last_logit_only=False,
            quantized_kv=True, kv_group=kv_group))

        def step(params, tokens, cache):
            # pos operand is dead on the paged path: positions ride in
            # the cache (per request), broadcast over the layer scan
            return zoo.decode_model(params, tokens, self.cfg, cache,
                                    jnp.int32(0))
        self._step = jax.jit(step, donate_argnums=(2,))
        self._key = jax.random.PRNGKey(self.seed)
        self.steps_run = 0
        # positions the LAST decode step actually served (requests that
        # retired within the step included) -- the per-step KV-traffic
        # ground truth benchmarks read; [] when the step decoded nothing
        self.last_positions: List[int] = []

    @property
    def pool(self):
        return self.scheduler.pool

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its id.  Total length must fit the
        per-request page-table width (``max_len`` slots)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = prompt.size + int(max_new_tokens)
        if total > self.max_len:
            raise ValueError(f"prompt+new = {total} exceeds "
                             f"max_len={self.max_len}")
        return self.scheduler.submit(
            prompt, max_new_tokens,
            eos_id if eos_id is not None else self.eos_id)

    # -- sampling -----------------------------------------------------------

    def _sample(self, lg: np.ndarray) -> int:
        """One token from one (V,) logit row (greedy at temperature 0,
        matching ``ServeEngine``'s argmax tie-breaking)."""
        if self.temperature <= 0:
            return int(np.argmax(lg))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(
            sub, jnp.asarray(lg) / self.temperature))

    # -- one engine step ----------------------------------------------------

    def _prefill_request(self, req) -> None:
        """Prefill a newly admitted request's prefix (page-aligned
        right-padded bucket; causal attention keeps pad columns out of
        real logits) and scatter its quantized cache into its pages."""
        prefix = req.prefix
        ln = prefix.size
        bucket = self.pool.pages_for(ln) * self.page_size
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :ln] = prefix
        logits, cache_q = self._prefill(self.params,
                                        {"tokens": jnp.asarray(toks)})
        self.pool.write_prefill(cache_q, req.pages)
        nxt = self._sample(np.asarray(logits[0, ln - 1]))
        req.generated.append(nxt)
        req.next_token = nxt

    def step(self) -> int:
        """Admit + prefill arrivals, one batched decode for everyone
        running, retire finishers.  Returns decoded request count."""
        sched = self.scheduler
        for req in sched.admit():
            self._prefill_request(req)
            if req.done:
                sched.retire(req)
        for req in list(sched.running):
            if req.status == "running":      # a victim may drop mid-loop
                sched.ensure_capacity(req)
        running = list(sched.running)
        self.last_positions = [req.position for req in running]
        if not running:
            return 0
        b, npp = self.max_batch, self.max_pages_per_req
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        page_table = np.zeros((b, npp), np.int32)   # pad rows park on page 0
        for row, req in enumerate(running):
            tokens[row, 0] = req.next_token
            positions[row] = req.position
            page_table[row, :len(req.pages)] = req.pages
        L = self.cfg.n_layers
        cache = self.pool.device_state()
        cache["page_table"] = jnp.tile(
            jnp.asarray(page_table)[None], (L, 1, 1))
        cache["positions"] = jnp.tile(jnp.asarray(positions)[None], (L, 1))
        logits, new_cache = self._step(self.params, jnp.asarray(tokens),
                                       cache)
        self.pool.set_device_state(new_cache)
        lg = np.asarray(logits[:, 0].astype(jnp.float32))
        for row, req in enumerate(running):
            nxt = self._sample(lg[row])
            req.generated.append(nxt)
            req.next_token = nxt
            if req.done:
                sched.retire(req)
        self.steps_run += 1
        return len(running)

    # -- drive to completion ------------------------------------------------

    def run(self, max_steps: int = 100000) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: prompt+generated}.  Admission can always make progress
        when nothing is running (all pages are free then), so the step
        bound only guards against bugs."""
        steps = 0
        while self.scheduler.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine failed to drain")
        return {rid: req.output
                for rid, req in self.scheduler.finished.items()}
