"""Batched serving engine: prefill -> decode with the packed weight plane.

This is the runtime the decode_* and long_* dry-run shapes lower:
``serve_step`` is one new token against a seq_len KV cache (or SSM state).
Weights can be physically packed (PackedTensor leaves -- HBM holds the
low-bit codes, the paper's memory-bandwidth reduction) and the KV cache
can be Posit(8,0)-quantized end-to-end (``quantized_kv=True``): prefill
returns codes+scales (one-shot ``zoo.quantize_cache`` fused into the
prefill jit, before ``_pad_cache``), decode writes the quantized layout
incrementally and reads only the live prefix of it per step (the
length-aware paths in ``models/attention``) -- the bf16 cache never
exists in HBM.

The engine itself does simple static batching with per-request lengths
masked by position -- enough to serve real batched traffic in the
examples while keeping the step function identical to the dry-run cell.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.policy import PrecisionPolicy
from ..models import ssm
from ..models import zoo
from ..obs import MetricRegistry, NULL_RECORDER, bind_counters
from .scheduler import PREFILLING, RUNNING

__all__ = ["build_prefill_step", "build_prefill_chunk_step",
           "build_serve_step", "ServeEngine", "ContinuousEngine"]


def build_prefill_step(cfg: ModelConfig, last_logit_only: bool = False,
                       quantized_kv: bool = False,
                       kv_group: Optional[int] = None,
                       quantized_state: bool = False):
    """(params, batch) -> (logits, cache): full-sequence forward that also
    materializes the KV cache / SSM state.

    ``last_logit_only``: return logits only for the final position -- the
    only one generation needs.  XLA pushes the slice up through the
    readout matmul, eliminating ~(S-1)/S of lm_head FLOPs and the
    (B, S, vocab) buffer (a §Perf hillclimb lever for prefill cells).

    ``quantized_kv``: quantize the returned KV cache to posit8 codes +
    ``kv_group``-grouped scales inside the same jit (XLA fuses the
    quantize into the cache write, so the bf16 cache is a transient,
    not an output).  ``quantized_state`` extends the same one-shot
    quantization to recurrent-state leaves (``ssm.quantize_state``);
    decode then round-trips the state through posit8 every step --
    the contiguous twin of the paged pool's state slabs."""

    def prefill(params, batch):
        logits, cache, _ = zoo.apply_model(params, batch, cfg, mode="prefill",
                                           cache=None)
        if last_logit_only:
            logits = logits[:, -1:]
        if quantized_kv:
            cache = zoo.quantize_cache(cache, kv_group,
                                       quantize_state=quantized_state)
        return logits, cache

    return prefill


def build_prefill_chunk_step(cfg: ModelConfig,
                             kv_group: Optional[int] = None,
                             paged: bool = False):
    """(params, tokens (1, C), ctx, start (1,)) -> the chunk-prefill step
    of chunked paged prefill: forward one CHUNK of C tokens at absolute
    positions ``start .. start+C-1``, attending causally to ``ctx`` (the
    request's already-prefilled prefix) plus the chunk itself.

    ``paged=False`` (carry, the engine default): ``ctx`` is the bf16 KV
    carry ``{"k", "v"}`` stacked (L, 1, T, Kh, Dh) with T == start.
    Returns (logits (1, C, V), chunk_kv, chunk_q): ``chunk_kv`` extends
    the carry for the next chunk and ``chunk_q`` (posit8 codes+scales,
    quantized inside the jit) scatters into pages via
    ``PagedKVPool.write_chunk``.  Chunk logits agree BITWISE with a
    monolithic prefill of the same prefix.

    ``paged=True``: ``ctx`` carries the pool leaves + ``page_table``
    (leaves lead with the layer-scan axis, like the paged decode cache);
    the chunk is quantized and scattered in-jit, attention reads prefix
    + chunk back through the page table, and (logits, updated_ctx) is
    returned -- zero extra residency, posit8-accurate context.
    Attention-only: recurrent state never lands in pages, so it cannot
    be re-read through a page table -- stateful families chunk on the
    carry path, where ``ctx`` is the family's ``zoo.init_cache`` pytree
    (rwkv state stack / hybrid group caches) and the f32 state rides
    the carry chunk to chunk (sequential recurrences make the chunked
    state BITWISE the monolithic one).
    """
    if paged and cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"prefill_context='pages' re-reads the prefix through the "
            f"page table, but family {cfg.family!r} carries recurrent "
            f"state that never lands in pages: chunk on the carry path")
    if cfg.rope_kind != "default":
        raise ValueError("chunked prefill serves 1-D token streams "
                         f"(rope_kind={cfg.rope_kind!r})")

    def chunk_step(params, tokens, ctx, start):
        c = tokens.shape[1]
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        batch = {"tokens": tokens, "positions": positions}
        logits, new_cache, _ = zoo.apply_model(
            params, batch, cfg, mode="prefill_chunk", cache=ctx)
        if paged:
            return logits, new_cache
        return logits, new_cache, zoo.quantize_cache(new_cache, kv_group)

    return chunk_step


def build_serve_step(cfg: ModelConfig, ragged: bool = False,
                     sample: bool = False):
    """(params, tokens (B,1), cache, pos) -> (logits, new_cache).

    ``ragged=True`` adds a trailing ``pad`` operand ((B,) left-pad
    widths): RoPE positions shift per request and pad cache slots are
    masked, so a left-padded mixed-length batch decodes like its
    unpadded per-request selves.

    ``sample=True`` fuses sampling into the step: two more trailing
    operands ``(key, temperature)`` and the step returns
    ``(next_tokens (B, 1) int32, new_cache)`` instead of logits -- the
    ``(B, vocab)`` logits never leave the device.  ``temperature`` is a
    traced scalar (one compiled step serves both regimes;
    ``lax.cond`` picks greedy argmax vs seeded categorical at run
    time), and both branches keep the host sampler's exact semantics:
    first-occurrence argmax tie-breaking, categorical over
    ``logits / temperature`` in the logits' own dtype."""

    def _next(logits, key, temperature):
        lg = logits[:, -1]
        return jax.lax.cond(
            temperature > 0,
            lambda: jax.random.categorical(
                key, lg / temperature).astype(jnp.int32),
            lambda: jnp.argmax(lg, -1).astype(jnp.int32))[:, None]

    if ragged:
        if sample:
            def serve_step(params, tokens, cache, pos, pad, key, temperature):
                logits, cache = zoo.decode_model(params, tokens, cfg, cache,
                                                 pos, pad)
                return _next(logits, key, temperature), cache
        else:
            def serve_step(params, tokens, cache, pos, pad):
                return zoo.decode_model(params, tokens, cfg, cache, pos, pad)
    elif sample:
        def serve_step(params, tokens, cache, pos, key, temperature):
            logits, cache = zoo.decode_model(params, tokens, cfg, cache, pos)
            return _next(logits, key, temperature), cache
    else:
        def serve_step(params, tokens, cache, pos):
            return zoo.decode_model(params, tokens, cfg, cache, pos)

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Static-batch serving with greedy/temperature sampling."""

    cfg: ModelConfig
    params: Any
    max_len: int = 2048
    # posit8 KV cache end-to-end: prefill returns codes+scales, decode
    # reads only the live prefix of them per step.  The scale grouping
    # follows ``policy.group_size`` (the weight plane's grid).
    quantized_kv: bool = False
    # posit8 recurrent state too (ssm/hybrid): prefill quantizes the
    # final state once, decode round-trips it through posit8 every step
    # -- the static oracle of the paged pool's state slabs
    quantized_state: bool = False
    policy: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        self._prefill = jax.jit(build_prefill_step(
            self.cfg, last_logit_only=True,
            quantized_kv=self.quantized_kv, kv_group=kv_group,
            quantized_state=self.quantized_state))
        self._step = jax.jit(build_serve_step(self.cfg))
        self._step_ragged = jax.jit(build_serve_step(self.cfg, ragged=True))
        # generate() runs on the fused-sampling variants: tokens come
        # back (B, 1) int32 and accumulate on device; the (B, vocab)
        # logits never cross to host
        self._gen_step = jax.jit(build_serve_step(self.cfg, sample=True))
        self._gen_step_ragged = jax.jit(
            build_serve_step(self.cfg, ragged=True, sample=True))

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0, key=None,
                 lengths=None) -> np.ndarray:
        """tokens: (B, S0) prompt -> (B, S0+steps) completed.

        ``lengths``: optional (B,) true prompt lengths of a LEFT-padded
        ragged batch (request b occupies ``tokens[b, S0-lengths[b]:]``).
        Pad tokens are masked out of attention and RoPE positions start
        at each request's first real token, so a mixed-length batch
        generates exactly what per-request calls would."""
        b, s0 = tokens.shape
        batch = {"tokens": tokens}
        pad = None
        if lengths is not None:
            if self.cfg.family not in ("dense", "moe") or \
                    self.cfg.rope_kind != "default":
                raise ValueError(
                    "ragged prompts need a pure-attention family with "
                    "default RoPE (SSM state would still absorb pads)")
            lengths = jnp.asarray(lengths, jnp.int32)
            pad = (s0 - lengths).astype(jnp.int32)          # (B,)
            idx = jnp.arange(s0, dtype=jnp.int32)[None]
            batch["positions"] = jnp.maximum(idx - pad[:, None], 0)
            batch["kv_mask"] = idx >= pad[:, None]
        # prefill is unconditional for every model family: it returns the
        # populated KV cache / SSM state (already posit8 codes+scales
        # under quantized_kv) that decode continues from.  Left padding
        # keeps the LAST column the last real token of every request, so
        # the last_logit_only logits feed sampling for ragged batches too.
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, b)
        last = jnp.argmax(logits, -1).astype(jnp.int32)     # (B, 1)
        key = key if key is not None else jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        # device-resident loop: each fused step returns the (B, 1)
        # sampled token that feeds the next step; tokens accumulate on
        # device and transfer ONCE at the end -- no per-step logits (or
        # token) sync.  The key splits unconditionally (same sequence
        # the host sampler consumed when temperature > 0; unused at 0).
        outs = [jnp.asarray(tokens)]
        for i in range(steps):
            outs.append(last)
            key, sub = jax.random.split(key)
            if pad is None:
                last, cache = self._gen_step(
                    self.params, last, cache, jnp.int32(s0 + i), sub, temp)
            else:
                last, cache = self._gen_step_ragged(
                    self.params, last, cache, jnp.int32(s0 + i), pad, sub,
                    temp)
        return np.asarray(jnp.concatenate(outs, axis=1))

    # cache leaves with a sequence axis, all laid out (L, B, S, H, ...):
    # bf16 k/v, posit8 codes, and their (..., Gs) scale tensors
    _SEQ_KEYS = frozenset(
        {"k", "v", "k_codes", "v_codes", "k_scale", "v_scale"})
    # scale leaves pad with the pool's neutral scale 1.0, not jnp.pad's
    # default 0.0: a zero po2 scale in a padded slot silently dequantizes
    # ANY code written there to 0 (only the positional mask was hiding
    # it), and the paged pool initializes scales to 1.0 -- the two
    # planes must share one convention.
    _SCALE_KEYS = frozenset({"k_scale", "v_scale"})

    def _pad_cache(self, cache, b):
        """Grow prefill-length KV buffers to max_len for decode.

        Structure-aware: pads by cache KEY (the seq axis is always axis 2
        of the stacked (L, B, S, H, ...) layout) instead of guessing from
        ndim/shape/dtype -- scale tensors pad on the right rank and SSM /
        RWKV states (no seq axis, no KV keys) pass through untouched."""
        def pad(key, x):
            if key in self._SEQ_KEYS and x.shape[2] < self.max_len:
                pad_width = [(0, 0)] * x.ndim
                pad_width[2] = (0, self.max_len - x.shape[2])
                fill = 1.0 if key in self._SCALE_KEYS else 0.0
                return jnp.pad(x, pad_width, constant_values=fill)
            return x

        def rec(node):
            if isinstance(node, dict):
                return {key: (rec(val) if isinstance(val, dict)
                              else pad(key, val))
                        for key, val in node.items()}
            return node

        return rec(cache)


# ---------------------------------------------------------------------------
# Continuous batching over the paged posit8 KV pool
# ---------------------------------------------------------------------------

def _trace_counted(fn, counts: Dict[str, int], name: str):
    """Wrap ``fn`` with a Python-side tracing counter before handing it
    to ``jax.jit``: the wrapper body runs only while jax TRACES the
    function (steady-state dispatches replay the compiled executable
    without re-entering Python), so ``counts[name]`` is exactly the
    (re)trace count.  This is the compile-count sentinel bench_serve
    asserts stays flat across the measured window -- a new shape bucket
    or a leaked weak-type/python-scalar operand shows up as a count
    bump at the diff that introduced it, not as an unattributable p99
    shift."""
    counts[name] = 0

    @functools.wraps(fn)
    def counted(*args, **kwargs):
        counts[name] += 1
        return fn(*args, **kwargs)

    return counted


def _device_only(on: bool):
    """A FRESH ``jax.transfer_guard("disallow")`` context when ``on``
    (jax guard contexts are single-use, so each guarded window needs
    its own), else a no-op.  Under the guard every IMPLICIT transfer
    raises -- a numpy or python-scalar operand silently uploaded into a
    dispatch, a device value silently pulled to host -- while the
    sanctioned explicit escapes (``jnp.asarray`` staging, the
    epoch-cache's page-table upload, ``jax.device_get`` of the sampled
    tokens) stay legal."""
    return jax.transfer_guard("disallow") if on else contextlib.nullcontext()


@functools.partial(jax.jit, donate_argnums=(0,))
def _ctx_write(buf: jax.Array, chunk: jax.Array, start) -> jax.Array:
    """dynamic_update_slice one bf16 KV chunk (L, 1, C, Kh, Dh) into the
    preallocated prefill carry at seq offset ``start``.  The carry is
    donated, so XLA updates the resident buffer instead of copying the
    whole prefix per chunk (the old per-chunk concatenate was O(T^2)
    bytes over a T-token prefill)."""
    return jax.lax.dynamic_update_slice(buf, chunk, (0, 0, start, 0, 0))


def _build_decode_loop(cfg: ModelConfig, temperature: float, k_steps: int):
    """Build the device-resident K-step decode dispatch of the
    continuous engine.

    (params, tokens (B,1), positions (B,), cache {pool leaves},
     page_table (B,NP), slab_table (B,), done (B,) bool, budget (B,),
     eos (B,), rids (B,), gen_idx (B,), key)
      -> (sampled (B, K) int32, new cache)

    One jitted call runs ``k_steps`` decode+sample iterations in a
    ``lax.scan``: fused sampling (greedy argmax / per-request seeded
    categorical at build-time ``temperature``), device-side position
    bumps, and an on-device done-mask.  A row finishes mid-scan when it
    samples its ``eos`` id or exhausts its remaining token ``budget``;
    finished (and padded) rows freeze their token/position and re-map
    their page-table row to the parking page, so their remaining
    iterations write page 0 at position 0 -- no-op DMAs that cannot
    touch live pages (paged_kv.PARKING_PAGE).  The host syncs only the
    (B, K) token buffer per dispatch; the (B, vocab) logits never leave
    the device.

    Page kinds (``serve/paged_kv.py``): attention layers read/write the
    paged KV plane through ``page_table``; recurrent layers (ssm /
    hybrid) gather their quantized state slab by ``slab_table`` row into
    the step's per-layer cache, run the dequantize -> recur ->
    requantize round-trip inside the model, and scatter the slab back
    -- the scan carry holds the WHOLE slab plane, so state stays
    device-resident across all K iterations.  Done rows re-map to the
    parking slab (slab 0), the state twin of the parking page: their
    writes race only each other over a buffer nobody reads.

    Categorical sampling draws row r's token i from the per-request
    stream ``fold_in(fold_in(key, rids[r]), gen_idx[r] + i)`` -- a
    function of (seed, request, token index) only, so the sampled
    sequence is invariant to K, batching and scheduling.
    """
    from .paged_kv import PARKING_PAGE, PARKING_SLAB, _POOL_KEYS
    has_state = cfg.family in ("ssm", "hybrid")
    has_kv = cfg.family != "ssm"
    attn_key = f"b{cfg.attn_every // 2}" if cfg.family == "hybrid" else None

    def loop(params, tokens, positions, cache, page_table, slab_table,
             done, budget, eos, rids, gen_idx, key):
        def body(carry, _):
            tokens, positions, done, budget, gen_idx, cache = carry
            slab_idx = None
            state = None
            if has_state:
                slab_idx = jnp.where(done, PARKING_SLAB, slab_table)
                state = jax.tree.map(lambda leaf: leaf[:, slab_idx],
                                     cache["state"])
            if not has_kv:
                step_cache = state
            else:
                kv_leaves = {k: cache[k] for k in _POOL_KEYS}
                if has_state:
                    # hybrid: the attention sub-block reads the pool
                    # leaves; every other sub-block its gathered state
                    step_cache = dict(state)
                    step_cache[attn_key] = kv_leaves
                else:
                    step_cache = kv_leaves
                step_cache["page_table"] = jnp.where(
                    done[:, None], PARKING_PAGE, page_table)
                step_cache["positions"] = jnp.where(done, 0, positions)
            logits, new_cache = zoo.decode_model(
                params, tokens, cfg, step_cache, jnp.int32(0))
            if has_kv:
                new_cache.pop("page_table")
                new_cache.pop("positions")
            if not has_kv:
                cache = {"state": jax.tree.map(
                    lambda buf, new: buf.at[:, slab_idx].set(new),
                    cache["state"], new_cache)}
            elif has_state:
                kv = new_cache.pop(attn_key)
                new_state = jax.tree.map(
                    lambda buf, new: buf.at[:, slab_idx].set(new),
                    cache["state"], new_cache)
                cache = {k: kv[k] for k in _POOL_KEYS}
                cache["state"] = new_state
            else:
                cache = new_cache
            lg = logits[:, 0].astype(jnp.float32)            # (B, V)
            if temperature > 0:
                sub = jax.vmap(lambda r, i: jax.random.fold_in(
                    jax.random.fold_in(key, r), i))(rids, gen_idx)
                nxt = jax.vmap(lambda k_, row: jax.random.categorical(
                    k_, row / temperature))(sub, lg).astype(jnp.int32)
            else:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            nxt = jnp.where(done, tokens[:, 0], nxt)         # freeze dead rows
            budget = jnp.where(done, budget, budget - 1)
            new_done = done | (nxt == eos) | (budget <= 0)
            positions = jnp.where(done, positions, positions + 1)
            gen_idx = jnp.where(done, gen_idx, gen_idx + 1)
            return ((nxt[:, None], positions, new_done, budget, gen_idx,
                     cache), nxt)
        carry0 = (tokens, positions, done, budget, gen_idx, cache)
        (_, _, _, _, _, cache), toks = jax.lax.scan(
            body, carry0, None, length=k_steps)
        return toks.T, cache                                 # (B, K)

    return loop


def _decode_horizon(req, decode_steps: int) -> int:
    """Pages to pre-claim for: the decode slots the next dispatch can
    write for ``req`` -- at most ``decode_steps``, capped by its
    remaining token budget (a row past its budget freezes on the
    parking page and writes nothing)."""
    return min(decode_steps,
               max(req.max_new_tokens - len(req.generated), 1))


class _PageTableCache:
    """Epoch-cached device page table: ``get`` re-uploads the (B, NP)
    table only when the scheduler epoch or the running-row order
    changed -- an unchanged (epoch, rows) pair means every row is
    bit-identical to the resident copy, so the cached device array is
    reused across dispatches (and across page handoffs on the decode
    worker, which keys on its runner's epoch the same way).  The (B,)
    slab table rides the same cache entry: a row's state-slab id can
    only change on the same transitions that bump the epoch."""

    def __init__(self):
        self.dev = None
        self.slab_dev = None
        self.epoch = -1
        self.rows: List[int] = []

    def get(self, running, epoch: int, b: int, n_pages_per_req: int):
        """-> (page table, slab table, uploaded?) for the rid-ordered
        batch."""
        rows = [req.rid for req in running]
        if self.dev is None or epoch != self.epoch or rows != self.rows:
            page_table = np.zeros((b, n_pages_per_req), np.int32)
            slab_table = np.zeros((b,), np.int32)
            for row, req in enumerate(running):
                page_table[row, :len(req.pages)] = req.pages
                if req.slab is not None:
                    slab_table[row] = req.slab
            self.dev = jnp.asarray(page_table)
            self.slab_dev = jnp.asarray(slab_table)
            self.epoch = epoch
            self.rows = rows
            return self.dev, self.slab_dev, True
        return self.dev, self.slab_dev, False


def _dispatch_decode_loop(loop, params, pool, running, b: int,
                          pt_cache: _PageTableCache, epoch: int,
                          n_pages_per_req: int, base_key):
    """Launch one K-step decode dispatch for the rid-ordered ``running``
    batch: build the (B,)-shaped host operands, fetch the epoch-cached
    page table, call the jitted loop (donating the pool cache) and park
    the updated leaves back on the pool.  Returns the in-flight dispatch
    record -- the (B, K) token buffer is still a device future, so the
    caller can overlap host work (the disaggregated engine runs a whole
    prefill chunk here) before syncing it with ``_apply_decode_tokens``.
    Shared by ``ContinuousEngine.step`` and the disaggregated
    ``DecodeWorker``; the batch-array layout and replay loop living in
    one place is what keeps their temperature-0 outputs bitwise equal."""
    tokens = np.zeros((b, 1), np.int32)
    positions = np.zeros((b,), np.int32)
    done = np.ones((b,), bool)           # padding rows stay dead
    budget = np.zeros((b,), np.int32)
    eos = np.full((b,), -1, np.int32)    # -1: matches no vocab id
    rids = np.zeros((b,), np.int32)
    gen_idx = np.zeros((b,), np.int32)
    for row, req in enumerate(running):
        tokens[row, 0] = req.next_token
        positions[row] = req.position
        done[row] = False
        budget[row] = req.max_new_tokens - len(req.generated)
        if req.eos_id is not None:
            eos[row] = req.eos_id
        rids[row] = req.rid
        gen_idx[row] = len(req.generated)
    dev_table, slab_table, uploaded = pt_cache.get(
        running, epoch, b, n_pages_per_req)
    toks_dev, new_cache = loop(
        params, jnp.asarray(tokens), jnp.asarray(positions),
        pool.device_state(), dev_table, slab_table, jnp.asarray(done),
        jnp.asarray(budget), jnp.asarray(eos), jnp.asarray(rids),
        jnp.asarray(gen_idx), base_key)
    pool.set_device_state(new_cache)
    return {"running": running, "budget": budget, "toks_dev": toks_dev,
            "uploaded": int(uploaded)}


def _apply_decode_tokens(disp, toks: np.ndarray, retire) -> int:
    """Replay the device done-logic of a dispatch on host: walk each
    row's (K,) tokens until its budget or EOS froze it (later slots are
    frozen copies the scan never wrote anywhere live), retiring done
    requests through ``retire``.  Returns the decoded request count."""
    k_steps = toks.shape[1]
    for row, req in enumerate(disp["running"]):
        for j in range(min(k_steps, int(disp["budget"][row]))):
            nxt = int(toks[row, j])
            req.generated.append(nxt)
            req.next_token = nxt
            if req.done:
                break
        if req.done:
            retire(req)
    return len(disp["running"])


class _ChunkPrefillMixin:
    """Chunked paged prefill, shared verbatim by ``ContinuousEngine``
    and the disaggregated ``PrefillWorker`` (``serve/disagg.py``).  The
    host object provides: ``cfg``, ``params``, ``scheduler`` (and its
    ``pool``), ``page_size``, ``max_pages_per_req``,
    ``prefill_chunk_tokens``, ``prefill_context``, ``temperature``,
    ``_base_key``, the jitted ``_chunk_step`` / ``_chunk_step_paged``,
    the ``_prefill_ctx`` carry dict, a ``prefill_tokens_computed``
    counter and a ``_trace`` recorder.  One implementation is what makes the disaggregated
    engine's temperature-0 outputs bitwise the interleaved engine's:
    both prefill paths run the exact same chunk code."""

    def _empty_ctx(self, width: int = 0):
        # the family's own zero cache: dense/moe {"k","v"} stacks (with
        # distinct buffers -- k and v are donated independently to
        # _ctx_write, so they must not alias), rwkv the zero state
        # stack, hybrid the per-group mix of both
        return zoo.init_cache(self.cfg, 1, width)

    @property
    def _attn_key(self) -> str:
        """Sub-block key of the attention layer inside a hybrid group
        (``models.transformer._group_layout`` puts it mid-group)."""
        return f"b{self.cfg.attn_every // 2}"

    def _grow_ctx(self, ctx, kv, start: int, ln: int):
        """Fold one non-final chunk's cache into the prefill carry.
        KV planes GROW (dynamic-update-slice into a carry preallocated
        once at the prompt's page-rounded width); recurrent state is
        REPLACED wholesale (the chunk's final state is the whole
        context the next chunk needs)."""
        if not self.pool.has_kv:
            return kv                    # rwkv: state stack replaces
        if not self.pool.has_state:      # dense/moe: pure KV growth
            if ctx["k"].shape[2] == 0:
                # preallocate ONCE at the prompt's page-rounded
                # length; later chunks dynamic-update-slice into the
                # donated buffer.  (The first chunk always runs on
                # the width-0 ctx, so single-chunk prefills never
                # touch -- or trace -- the preallocated shape.)
                ctx = self._empty_ctx(
                    self.pool.pages_for(ln) * self.page_size)
            return {"k": _ctx_write(ctx["k"], kv["k"], jnp.int32(start)),
                    "v": _ctx_write(ctx["v"], kv["v"], jnp.int32(start))}
        # hybrid: the attention sub grows, the mamba subs replace
        ak = self._attn_key
        sub = ctx[ak]
        if sub["k"].shape[2] == 0:
            sub = self._empty_ctx(
                self.pool.pages_for(ln) * self.page_size)[ak]
        out = {k: v for k, v in kv.items() if k != ak}
        out[ak] = {"k": _ctx_write(sub["k"], kv[ak]["k"], jnp.int32(start)),
                   "v": _ctx_write(sub["v"], kv[ak]["v"], jnp.int32(start))}
        return out

    def _sample(self, lg: np.ndarray, req) -> int:
        """One token from one (V,) logit row -- the HOST twin of the
        device loop's fused sampler, used only for the first token at
        prefill completion.  Greedy matches jnp/np argmax tie-breaking;
        categorical draws from the same per-request stream
        ``fold_in(fold_in(base_key, rid), token_index)`` the device
        scan uses, so a request's sampled sequence does not depend on
        where (host or device) or in which dispatch a token fell."""
        if self.temperature <= 0:
            return int(np.argmax(lg))
        sub = jax.random.fold_in(
            jax.random.fold_in(self._base_key, req.rid), len(req.generated))
        return int(jax.random.categorical(
            sub, jnp.asarray(lg, jnp.float32) / self.temperature))

    def _prefill_chunk(self, req) -> int:
        """Run at most ONE prefill chunk for ``req``: allocate the pages
        the chunk's slots land in (lazy, can preempt younger requests),
        forward the chunk against the request's prefilled context, and
        scatter its quantized KV into pages.  Completes prefill (samples
        the first token, PREFILLING -> RUNNING) when the chunk covers
        the prefix's last real token.  Returns the prefill tokens spent
        (the padded chunk width; 0 if ``req`` was preempted before any
        compute)."""
        sched = self.scheduler
        prefix = req.prefix
        ln = prefix.size
        # the cursor starts past the matched shared pages of a prefix-
        # cache hit (page-aligned by construction), so a hit computes
        # only its un-cached remainder
        start = req.prefilled
        stateful = self.pool.has_state
        if stateful:
            # stateful chunks are UNPADDED: every forwarded token runs
            # through the recurrent state, so pad tokens would corrupt
            # it (the KV scatter pads the trailing partial page block
            # inside write_chunk instead)
            c = ln - start if self.prefill_chunk_tokens is None \
                else min(self.prefill_chunk_tokens, ln - start)
        elif self.prefill_chunk_tokens is None:
            # monolithic: one chunk covering every remaining page slot
            c = self.pool.pages_for(ln) * self.page_size - start
        else:
            c = self.prefill_chunk_tokens
        real = min(c, ln - start)
        if not sched.ensure_prefill_capacity(req, start + real):
            return 0                     # self-preempted: pool too dry
        toks = np.zeros((1, c), np.int32)
        toks[0, :real] = prefix[start:start + real]
        start_arr = jnp.full((1,), start, jnp.int32)
        if self.prefill_context == "pages":
            pt = np.zeros((1, self.max_pages_per_req), np.int32)
            pt[0, :len(req.pages)] = req.pages
            cache = self.pool.device_state()
            # (1, NP), untiled: the layer scan broadcasts it
            cache["page_table"] = jnp.asarray(pt)
            logits, new_cache = self._chunk_step_paged(
                self.params, jnp.asarray(toks), cache, start_arr)
            self.pool.set_device_state(
                {key: new_cache[key] for key in
                 ("k_codes", "v_codes", "k_scale", "v_scale")})
        else:
            ctx = self._prefill_ctx.get(req.rid)
            if start == 0 or ctx is None:
                ctx = self._empty_ctx()
            logits, kv, chunk_q = self._chunk_step(
                self.params, jnp.asarray(toks), ctx, start_arr)
            if self.pool.has_kv:
                self.pool.write_chunk(
                    chunk_q[self._attn_key] if stateful else chunk_q,
                    req.pages, start)
            if start + real < ln:        # full chunk: extend the carry
                self._prefill_ctx[req.rid] = self._grow_ctx(
                    ctx, kv, start, ln)
            elif stateful:
                # prefill completion writes the carried state into the
                # request's slab ONCE, quantized exactly like the
                # static oracle's post-prefill quantize_cache
                state_part = kv if not self.pool.has_kv else \
                    {k: v for k, v in kv.items() if k != self._attn_key}
                self.pool.write_state(
                    ssm.quantize_state(state_part, self.pool.kv_group),
                    req.slab)
        req.prefilled = start + real
        self.prefill_tokens_computed += real
        self._trace.event("PREFILL_CHUNK", rid=req.rid, start=start,
                          width=c, real=real)
        if req.prefilled == ln:
            self._prefill_ctx.pop(req.rid, None)
            nxt = self._sample(jax.device_get(logits[0, real - 1]), req)
            req.generated.append(nxt)
            req.next_token = nxt
            sched.prefill_complete(req)
        return c

    def _prefill_phase(self) -> List[Any]:
        """Chunked prefill, oldest first, inside the per-step token
        budget: at most ``prefill_chunk_tokens`` prefill tokens per step
        (None = whole prefixes, the monolithic behavior).  Returns the
        requests whose prefill COMPLETED this step (now RUNNING, first
        token sampled) and drops the bf16 carries of requests no longer
        mid-prefill (preempted or completed); a preemption victim
        re-prefills from chunk 0 on re-admission."""
        sched = self.scheduler
        budget = self.prefill_chunk_tokens
        spent = 0
        completed = []
        for req in [r for r in sched.running if r.status == PREFILLING]:
            while req.status == PREFILLING and \
                    (budget is None or spent < budget):
                spent += self._prefill_chunk(req)
            if req.status == RUNNING:
                completed.append(req)
        live = {r.rid for r in sched.running if r.status == PREFILLING}
        for rid in [r for r in self._prefill_ctx if r not in live]:
            del self._prefill_ctx[rid]
        return completed


@dataclasses.dataclass
class ContinuousEngine(_ChunkPrefillMixin):
    """Continuous-batching serving over a paged posit8 KV pool.

    The static ``ServeEngine`` batches a fixed set of requests against a
    dense ``max_len`` cache: every request pays worst-case KV memory and
    new arrivals wait for the whole batch.  This engine keeps ONE jitted
    decode step of shape ``max_batch`` alive and per step (a) ensures
    page capacity for the requests already running, (b) admits queued
    requests (FIFO, gated on unclaimed free pages), (c) prefills
    admitted requests in page-aligned CHUNKS inside a per-step token
    budget, (d) runs one batched paged decode for every running request
    at its OWN position, and (e) retires finished requests, returning
    their pages -- with LIFO preemption (free the youngest's pages,
    requeue it) when the pool runs dry.  See ``serve/scheduler.py`` for
    the policy and ``serve/paged_kv.py`` for the page layout and the
    chunk/page contract.

    Chunked paged prefill: ``prefill_chunk_tokens`` (a multiple of
    ``page_size`` that divides ``max_len``) bounds the prefill tokens
    one engine step may process, so a long-prompt arrival costs a chain
    of chunk-sized steps interleaved with decode instead of stalling
    every running request for a full prefill -- p99 DECODE-step latency
    is bounded by the chunk, not the longest prompt.  ``None`` (the
    default) prefills each admission in one whole-prefix chunk through
    the same code path (the PR 3 monolithic behavior).  The chunk's
    attention context is selected by ``prefill_context``:

      * ``"carry"`` (default): the already-prefilled prefix rides as a
        transient bf16 KV carry, so chunk logits -- and therefore
        temperature-0 tokens -- are BITWISE those of a monolithic
        prefill; the carry is dropped the moment prefill completes.
      * ``"pages"``: the chunk re-reads the prefix from its posit8
        pages (``attention.paged_prefill_blocked`` / the fused kernel
        under ``decode_impl='flash'``): zero extra residency, but the
        context is dequantized, so prompt logits carry quantization
        error and exact static parity is not guaranteed.

    Prefix caching (``prefix_cache=True``): whole prompt-prefix pages
    of completed prefills stay cached in the scheduler's
    ``PrefixIndex`` after retirement; a later request whose prompt
    starts with the same token blocks attaches them READ-ONLY and its
    chunk cursor starts past the match, so it computes -- and the
    admission gate budgets -- only the new pages (see the share/
    copy-on-write contract in ``serve/paged_kv.py``).  This implies
    ``prefill_context="pages"`` (the default under prefix caching):
    the remaining chunks must attend to the prefix THROUGH the shared
    posit8 pages, which hold bitwise the codes a cold run would write
    -- so temperature-0 outputs match a cache-off engine (also on the
    pages context) token for token.  The bf16 carry context cannot
    reproduce a prefix this request never forwarded, so
    ``prefill_context="carry"`` with ``prefix_cache=True`` is
    rejected.

    The KV plane is ALWAYS the posit8 paged pool (that is the point);
    weights pack per ``policy`` exactly like the static engine.  At
    temperature 0 with ``page_size == default_kv_block(max_len)`` of a
    static engine (and ``prefill_context="carry"``), outputs match
    per-request ``ServeEngine.generate`` token for token (the paged and
    contiguous block partitions -- and therefore the online-softmax
    accumulation order -- coincide, and chunked prefill replays the
    monolithic logits bitwise).
    """

    cfg: ModelConfig
    params: Any
    n_pages: int = 64
    page_size: Optional[int] = None
    max_batch: int = 8
    max_len: int = 512
    policy: Optional[PrecisionPolicy] = None
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    prefill_chunk_tokens: Optional[int] = None
    # None resolves to "carry" (bitwise static parity), or to "pages"
    # under prefix_cache (shared pages are only readable through the
    # page table)
    prefill_context: Optional[str] = None
    prefix_cache: bool = False
    # decode iterations per jitted dispatch: one host round trip drives
    # K on-device decode+sample steps (positions bump on device; rows
    # that hit EOS / budget mid-scan freeze and re-map their writes to
    # the parking page).  Temperature-0 outputs are identical for every
    # K; K only trades host round trips against (at most K-1) wasted
    # tail iterations per dispatch.
    decode_steps: int = 1
    # state slabs of the pool (recurrent/hybrid families): every
    # admitted request holds exactly ONE for its whole lifetime, so the
    # default -- one per batch slot -- means slab capacity never gates
    # admission below max_batch.  Ignored for pure-attention families.
    n_state_slabs: Optional[int] = None
    # observability (docs/observability.md): an ``obs.TraceRecorder``
    # capturing lifecycle events + step spans, or None for the shared
    # no-op recorder -- telemetry is host-side bookkeeping only, so
    # temperature-0 outputs are bitwise identical with tracing on or
    # off.  ``profile_annotations`` additionally wraps each decode
    # dispatch in ``jax.profiler.TraceAnnotation`` so device profiles
    # carry the engine's phase names.
    trace: Any = None
    profile_annotations: bool = False
    # runtime transfer guard (bench/test harness hook): when True, the
    # decode dispatch+sync windows run under a fresh
    # ``jax.transfer_guard("disallow")`` so any IMPLICIT host<->device
    # transfer on the decode critical path raises instead of silently
    # serializing.  Off by default: the first dispatch of a fresh
    # engine may legitimately move trace-time constants; benches flip
    # it on after warm-up (the steady-state window the discipline
    # governs).
    transfer_guard: bool = False

    # every public run counter; ``reset_counters`` and ``__post_init__``
    # derive from this registry, so adding a counter here is the WHOLE
    # change (the bench warm-up reset can never miss one again)
    _COUNTERS = (
        "steps_run",
        "prefill_tokens_computed",  # real tokens forwarded (cache hits
        #                             skip their matched prefix)
        "decode_dispatches",        # jitted decode-loop calls
        "page_table_uploads",       # (B, NP) host->device uploads
        "logits_host_bytes",        # device->host logits traffic
        #                             (stays 0: sampling is fused)
        "token_host_bytes",         # device->host sampled-token sync
    )

    def __post_init__(self):
        from ..kernels.flash_decode import default_kv_block
        from .paged_kv import PagedKVPool
        from .scheduler import Scheduler
        if self.cfg.frontend != "none":
            raise ValueError(
                "ContinuousEngine serves token prompts; vision/audio "
                "frontends need per-request frame/patch embeddings the "
                "request queue does not carry")
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        if self.page_size is None:
            self.page_size = default_kv_block(self.max_len)
        if self.max_len % self.page_size:
            rounded = -(-self.max_len // self.page_size) * self.page_size
            raise ValueError(
                f"max_len={self.max_len} must be a multiple of "
                f"page_size={self.page_size}: the page-table row maps "
                f"whole pages, so a partial final page cannot be "
                f"addressed -- round max_len up to {rounded} (what "
                f"launch/serve.py does) or pick a page size that "
                f"divides it")
        self.max_pages_per_req = self.max_len // self.page_size
        if self.prefill_chunk_tokens is not None:
            c = self.prefill_chunk_tokens
            if c <= 0 or c % self.page_size or self.max_len % c:
                raise ValueError(
                    f"prefill_chunk_tokens={c} must be a positive "
                    f"multiple of page_size={self.page_size} that "
                    f"divides max_len={self.max_len} (the chunk/page "
                    f"contract of serve/paged_kv.py)")
        kinds = PagedKVPool.page_kinds(self.cfg)  # rejects unknown families
        if self.prefill_context is None:
            self.prefill_context = "pages" if self.prefix_cache else "carry"
        if self.prefill_context not in ("carry", "pages"):
            raise ValueError(self.prefill_context)
        if "state" in kinds and self.prefill_context == "pages":
            raise ValueError(
                f"family {self.cfg.family!r} carries recurrent state, "
                f"which never lands in pages and cannot be re-read "
                f"through a page table: serve it with "
                f"prefill_context='carry' (which also rules out "
                f"prefix_cache -- a cached prefix cannot reproduce the "
                f"state of tokens this request never forwarded)")
        if self.prefix_cache and self.prefill_context == "carry":
            raise ValueError(
                "prefix_cache shares posit8 pages a hit request never "
                "forwarded itself, so its chunks can only attend to the "
                "prefix THROUGH the page table: use "
                "prefill_context='pages' (the default when prefix_cache "
                "is set)")
        if self.decode_steps < 1:
            raise ValueError(
                f"decode_steps={self.decode_steps} must be >= 1")
        # one registry spans every layer of this engine; the recorder
        # defaults to the shared no-op (one predicted branch per call)
        self.metrics = MetricRegistry()
        self._trace = self.trace if self.trace is not None else NULL_RECORDER
        if self._trace.enabled and self._trace.hist_registry is None:
            self._trace.hist_registry = self.metrics
        bind_counters(self, self.metrics, "engine")
        self._annotation = None
        if self.profile_annotations:
            from jax.profiler import TraceAnnotation
            self._annotation = TraceAnnotation
        n_slabs = 0
        if "state" in kinds:
            n_slabs = self.n_state_slabs \
                if self.n_state_slabs is not None else self.max_batch
        pool = PagedKVPool(self.cfg, self.n_pages, self.page_size, kv_group,
                           n_slabs=n_slabs)
        pool.register_gauges(self.metrics, "pool")
        self.scheduler = Scheduler(pool, self.max_batch,
                                   max_pages_per_req=self.max_pages_per_req,
                                   prefix_cache=self.prefix_cache,
                                   registry=self.metrics, trace=self._trace)
        # closed-form cache traffic of the LAST decode dispatch, per
        # page kind (the same models bench_serve ties against measured
        # bytes): KV pages + state slabs combined, and the state term
        # alone -- 2x slab bytes (read + rewrite) per live request,
        # independent of position
        self.metrics.gauge(
            "engine/kv_bytes_per_step_model",
            fn=lambda: self.pool.modeled_bytes_per_step(self.last_positions)
            if self.last_positions else 0.0)
        from .paged_kv import state_slab_bytes
        self.metrics.gauge(
            "engine/state_bytes_per_step_model",
            fn=lambda: 2.0 * state_slab_bytes(self.cfg, kv_group)
            * len(self.last_positions) if self.pool.has_state else 0.0)
        # compile-count sentinel: every jitted entry point is wrapped
        # with a tracing counter BEFORE jax.jit, so
        # ``trace_counts[name]`` counts (re)traces -- bench_serve
        # snapshots this after warm-up and asserts it stays flat across
        # the measured run (zero steady-state recompiles)
        self.trace_counts: Dict[str, int] = {}
        # chunk prefill steps: FULL chunk logits (the request's last real
        # token may sit anywhere inside the final chunk)
        self._chunk_step = jax.jit(_trace_counted(
            build_prefill_chunk_step(self.cfg, kv_group),
            self.trace_counts, "prefill_chunk"))
        # the paged-context variant is attention-only (the builder
        # rejects stateful families), so it exists only when selected
        self._chunk_step_paged = None
        if self.prefill_context == "pages":
            self._chunk_step_paged = jax.jit(_trace_counted(
                build_prefill_chunk_step(self.cfg, kv_group, paged=True),
                self.trace_counts, "prefill_chunk_paged"),
                donate_argnums=(2,))
        # per-request bf16 KV carries of requests mid-prefill (rid ->
        # {"k","v"} stacked (L,1,T,Kh,Dh)); dropped on completion or
        # preemption.  Bounded by the prefix of the few PREFILLING
        # requests -- the same transient a monolithic prefill held.
        self._prefill_ctx: Dict[int, Any] = {}

        # the device-resident K-step decode dispatch (fused sampling +
        # lax.scan over decode_steps iterations); only the pool cache
        # (arg 3) is donated -- the epoch-cached page table must stay
        # resident across dispatches
        self._decode_loop = jax.jit(_trace_counted(
            _build_decode_loop(self.cfg, self.temperature,
                               self.decode_steps),
            self.trace_counts, "decode_loop"),
            donate_argnums=(3,))
        self._base_key = jax.random.PRNGKey(self.seed)
        # epoch-cached device page table: re-uploaded only when the
        # scheduler epoch or the running-row order changed
        self._pt_cache = _PageTableCache()
        # positions the LAST decode dispatch started from (requests that
        # retired within the step included) -- the per-step KV-traffic
        # ground truth benchmarks read; [] when the step decoded nothing
        self.last_positions: List[int] = []
        # rids admitted by the LAST step (regression hook: a rid must
        # never show up in scheduler.preempted_log during the same step)
        self.last_admitted: List[int] = []

    @property
    def pool(self):
        return self.scheduler.pool

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its id.  Total length must fit the
        per-request page-table width (``max_len`` slots) -- validated by
        the scheduler, which knows the row width, so a direct scheduler
        user gets the same rejection at the same point."""
        return self.scheduler.submit(
            prompt, max_new_tokens,
            eos_id if eos_id is not None else self.eos_id)

    # -- one engine step ----------------------------------------------------

    def step(self) -> int:
        """One engine step: capacity for the running batch FIRST, then
        admission, chunked prefill within the token budget, ONE
        device-resident decode dispatch (``decode_steps`` fused
        decode+sample iterations) for everyone running, retirement.
        Returns decoded request count.

        The ordering is load-bearing: PR 3 admitted (and fully
        prefilled) newcomers BEFORE ensuring capacity for the running
        batch, so under pool pressure the newcomer took the last free
        page, was immediately preempted as the youngest victim, and its
        whole prefill was wasted -- every step while the pressure
        lasted.  Capacity-first means a newcomer is only admitted
        against pages the running batch did not need this step."""
        sched = self.scheduler
        tr = self._trace
        with tr.span("step"):
            # (1) grow the already-running requests' page tables
            # (pre-claim the whole decode_steps window: no page can be
            # missing mid-scan)
            with tr.span("capacity"):
                for req in list(sched.running):
                    if req.status == RUNNING:  # a victim may drop mid-loop
                        sched.ensure_capacity(
                            req,
                            horizon=_decode_horizon(req, self.decode_steps))
            # (2) admit against the unclaimed remainder
            with tr.span("admit"):
                self.last_admitted = [r.rid for r in sched.admit()]
            # (3) chunked prefill within the token budget; a request
            # whose whole budget fit the prefill (budget of 1 / instant
            # EOS) retires without ever reaching decode
            with tr.span("prefill"):
                for req in self._prefill_phase():
                    if req.done:
                        sched.retire(req)
            # (4) ONE batched K-step decode dispatch for everyone
            # RUNNING (newly promoted requests may still need pages
            # their decode window writes -- their admission gate already
            # reserved budget for the first write, so this never
            # preempts a same-step admission)
            running = []
            for req in list(sched.running):
                if req.status == RUNNING and sched.ensure_capacity(
                        req, horizon=_decode_horizon(req, self.decode_steps)):
                    running.append(req)
            self.last_positions = [req.position for req in running]
            if not running:
                return 0
            ann = self._annotation("decode_dispatch") \
                if self._annotation is not None else contextlib.nullcontext()
            with tr.span("decode_dispatch"), ann, \
                    _device_only(self.transfer_guard):
                disp = _dispatch_decode_loop(
                    self._decode_loop, self.params, self.pool, running,
                    self.max_batch, self._pt_cache, sched.epoch,
                    self.max_pages_per_req, self._base_key)
            self.decode_dispatches += 1
            self.page_table_uploads += disp["uploaded"]
            tr.event("DECODE_DISPATCH", batch=len(running),
                     k=self.decode_steps, uploaded=disp["uploaded"])
            with tr.span("decode_sync"), _device_only(self.transfer_guard):
                # the ONE sanctioned (B, K) host sync of the step
                toks = jax.device_get(disp["toks_dev"])
            self.token_host_bytes += toks.nbytes
            tr.event("DECODE_SYNC", token_bytes=toks.nbytes)
            n = _apply_decode_tokens(disp, toks, sched.retire)
            self.steps_run += 1
            return n

    # -- counters -----------------------------------------------------------

    def reset_counters(self) -> None:
        """Zero every run counter (bench warm-up hygiene: a warm request
        must not leak its pages/steps/preemptions into the measured
        run).  Every layer zeroes its OWN ``_COUNTERS`` registry --
        engine, scheduler, prefix index -- so a counter added to any of
        them resets without this method changing.  The pool's CURRENT
        allocation -- e.g. prefix pages the warm-up left cached --
        becomes the new peak baseline."""
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.pool.alloc_peak = self.pool.used_pages
        self.scheduler.reset_counters()
        # registry-wide sweep: clears span/SLO histograms too (callback
        # gauges are live reads and have nothing to reset)
        self.metrics.reset()

    # -- drive to completion ------------------------------------------------

    def run(self, max_steps: int = 100000) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: prompt+generated}.  Admission can always make progress
        when nothing is running (all pages are free then), so the step
        bound only guards against bugs."""
        steps = 0
        while self.scheduler.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine failed to drain")
        return {rid: req.output
                for rid, req in self.scheduler.finished.items()}
