"""Batched serving engine: prefill -> decode with the packed weight plane.

This is the runtime the decode_* and long_* dry-run shapes lower:
``serve_step`` is one new token against a seq_len KV cache (or SSM state).
Weights can be physically packed (PackedTensor leaves -- HBM holds the
low-bit codes, the paper's memory-bandwidth reduction) and the KV cache
can be Posit(8,0)-quantized end-to-end (``quantized_kv=True``): prefill
returns codes+scales (one-shot ``zoo.quantize_cache`` fused into the
prefill jit, before ``_pad_cache``), decode writes the quantized layout
incrementally and reads only the live prefix of it per step (the
length-aware paths in ``models/attention``) -- the bf16 cache never
exists in HBM.

The engine itself does simple static batching with per-request lengths
masked by position -- enough to serve real batched traffic in the
examples while keeping the step function identical to the dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.policy import PrecisionPolicy
from ..models import zoo
from .scheduler import PREFILLING, RUNNING

__all__ = ["build_prefill_step", "build_prefill_chunk_step",
           "build_serve_step", "ServeEngine", "ContinuousEngine"]


def build_prefill_step(cfg: ModelConfig, last_logit_only: bool = False,
                       quantized_kv: bool = False,
                       kv_group: Optional[int] = None):
    """(params, batch) -> (logits, cache): full-sequence forward that also
    materializes the KV cache / SSM state.

    ``last_logit_only``: return logits only for the final position -- the
    only one generation needs.  XLA pushes the slice up through the
    readout matmul, eliminating ~(S-1)/S of lm_head FLOPs and the
    (B, S, vocab) buffer (a §Perf hillclimb lever for prefill cells).

    ``quantized_kv``: quantize the returned KV cache to posit8 codes +
    ``kv_group``-grouped scales inside the same jit (XLA fuses the
    quantize into the cache write, so the bf16 cache is a transient,
    not an output)."""

    def prefill(params, batch):
        logits, cache, _ = zoo.apply_model(params, batch, cfg, mode="prefill",
                                           cache=None)
        if last_logit_only:
            logits = logits[:, -1:]
        if quantized_kv:
            cache = zoo.quantize_cache(cache, kv_group)
        return logits, cache

    return prefill


def build_prefill_chunk_step(cfg: ModelConfig,
                             kv_group: Optional[int] = None,
                             paged: bool = False):
    """(params, tokens (1, C), ctx, start (1,)) -> the chunk-prefill step
    of chunked paged prefill: forward one CHUNK of C tokens at absolute
    positions ``start .. start+C-1``, attending causally to ``ctx`` (the
    request's already-prefilled prefix) plus the chunk itself.

    ``paged=False`` (carry, the engine default): ``ctx`` is the bf16 KV
    carry ``{"k", "v"}`` stacked (L, 1, T, Kh, Dh) with T == start.
    Returns (logits (1, C, V), chunk_kv, chunk_q): ``chunk_kv`` extends
    the carry for the next chunk and ``chunk_q`` (posit8 codes+scales,
    quantized inside the jit) scatters into pages via
    ``PagedKVPool.write_chunk``.  Chunk logits agree BITWISE with a
    monolithic prefill of the same prefix.

    ``paged=True``: ``ctx`` carries the pool leaves + ``page_table``
    (leaves lead with the layer-scan axis, like the paged decode cache);
    the chunk is quantized and scattered in-jit, attention reads prefix
    + chunk back through the page table, and (logits, updated_ctx) is
    returned -- zero extra residency, posit8-accurate context.
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"chunked prefill needs a pure-attention cache; family "
            f"{cfg.family!r} carries SSM state")
    if cfg.rope_kind != "default":
        raise ValueError("chunked prefill serves 1-D token streams "
                         f"(rope_kind={cfg.rope_kind!r})")

    def chunk_step(params, tokens, ctx, start):
        c = tokens.shape[1]
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        batch = {"tokens": tokens, "positions": positions}
        logits, new_cache, _ = zoo.apply_model(
            params, batch, cfg, mode="prefill_chunk", cache=ctx)
        if paged:
            return logits, new_cache
        return logits, new_cache, zoo.quantize_cache(new_cache, kv_group)

    return chunk_step


def build_serve_step(cfg: ModelConfig, ragged: bool = False):
    """(params, tokens (B,1), cache, pos) -> (logits, new_cache).

    ``ragged=True`` adds a trailing ``pad`` operand ((B,) left-pad
    widths): RoPE positions shift per request and pad cache slots are
    masked, so a left-padded mixed-length batch decodes like its
    unpadded per-request selves."""

    if ragged:
        def serve_step(params, tokens, cache, pos, pad):
            return zoo.decode_model(params, tokens, cfg, cache, pos, pad)
    else:
        def serve_step(params, tokens, cache, pos):
            return zoo.decode_model(params, tokens, cfg, cache, pos)

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Static-batch serving with greedy/temperature sampling."""

    cfg: ModelConfig
    params: Any
    max_len: int = 2048
    # posit8 KV cache end-to-end: prefill returns codes+scales, decode
    # reads only the live prefix of them per step.  The scale grouping
    # follows ``policy.group_size`` (the weight plane's grid).
    quantized_kv: bool = False
    policy: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        self._prefill = jax.jit(build_prefill_step(
            self.cfg, last_logit_only=True,
            quantized_kv=self.quantized_kv, kv_group=kv_group))
        self._step = jax.jit(build_serve_step(self.cfg))
        self._step_ragged = jax.jit(build_serve_step(self.cfg, ragged=True))

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0, key=None,
                 lengths=None) -> np.ndarray:
        """tokens: (B, S0) prompt -> (B, S0+steps) completed.

        ``lengths``: optional (B,) true prompt lengths of a LEFT-padded
        ragged batch (request b occupies ``tokens[b, S0-lengths[b]:]``).
        Pad tokens are masked out of attention and RoPE positions start
        at each request's first real token, so a mixed-length batch
        generates exactly what per-request calls would."""
        b, s0 = tokens.shape
        batch = {"tokens": tokens}
        pad = None
        if lengths is not None:
            if self.cfg.family not in ("dense", "moe") or \
                    self.cfg.rope_kind != "default":
                raise ValueError(
                    "ragged prompts need a pure-attention family with "
                    "default RoPE (SSM state would still absorb pads)")
            lengths = jnp.asarray(lengths, jnp.int32)
            pad = (s0 - lengths).astype(jnp.int32)          # (B,)
            idx = jnp.arange(s0, dtype=jnp.int32)[None]
            batch["positions"] = jnp.maximum(idx - pad[:, None], 0)
            batch["kv_mask"] = idx >= pad[:, None]
        # prefill is unconditional for every model family: it returns the
        # populated KV cache / SSM state (already posit8 codes+scales
        # under quantized_kv) that decode continues from.  Left padding
        # keeps the LAST column the last real token of every request, so
        # the last_logit_only logits feed sampling for ragged batches too.
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, b)
        out = [np.asarray(tokens)]
        last = jnp.argmax(logits, -1).astype(jnp.int32)     # (B, 1)
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(steps):
            out.append(np.asarray(last))
            if pad is None:
                logits, cache = self._step(self.params, last,
                                           cache, jnp.int32(s0 + i))
            else:
                logits, cache = self._step_ragged(
                    self.params, last, cache, jnp.int32(s0 + i), pad)
            lg = logits[:, -1]
            if temperature > 0:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(
                    sub, lg / temperature)[:, None].astype(jnp.int32)
            else:
                last = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)

    # cache leaves with a sequence axis, all laid out (L, B, S, H, ...):
    # bf16 k/v, posit8 codes, and their (..., Gs) scale tensors
    _SEQ_KEYS = frozenset(
        {"k", "v", "k_codes", "v_codes", "k_scale", "v_scale"})
    # scale leaves pad with the pool's neutral scale 1.0, not jnp.pad's
    # default 0.0: a zero po2 scale in a padded slot silently dequantizes
    # ANY code written there to 0 (only the positional mask was hiding
    # it), and the paged pool initializes scales to 1.0 -- the two
    # planes must share one convention.
    _SCALE_KEYS = frozenset({"k_scale", "v_scale"})

    def _pad_cache(self, cache, b):
        """Grow prefill-length KV buffers to max_len for decode.

        Structure-aware: pads by cache KEY (the seq axis is always axis 2
        of the stacked (L, B, S, H, ...) layout) instead of guessing from
        ndim/shape/dtype -- scale tensors pad on the right rank and SSM /
        RWKV states (no seq axis, no KV keys) pass through untouched."""
        def pad(key, x):
            if key in self._SEQ_KEYS and x.shape[2] < self.max_len:
                pad_width = [(0, 0)] * x.ndim
                pad_width[2] = (0, self.max_len - x.shape[2])
                fill = 1.0 if key in self._SCALE_KEYS else 0.0
                return jnp.pad(x, pad_width, constant_values=fill)
            return x

        def rec(node):
            if isinstance(node, dict):
                return {key: (rec(val) if isinstance(val, dict)
                              else pad(key, val))
                        for key, val in node.items()}
            return node

        return rec(cache)


# ---------------------------------------------------------------------------
# Continuous batching over the paged posit8 KV pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousEngine:
    """Continuous-batching serving over a paged posit8 KV pool.

    The static ``ServeEngine`` batches a fixed set of requests against a
    dense ``max_len`` cache: every request pays worst-case KV memory and
    new arrivals wait for the whole batch.  This engine keeps ONE jitted
    decode step of shape ``max_batch`` alive and per step (a) ensures
    page capacity for the requests already running, (b) admits queued
    requests (FIFO, gated on unclaimed free pages), (c) prefills
    admitted requests in page-aligned CHUNKS inside a per-step token
    budget, (d) runs one batched paged decode for every running request
    at its OWN position, and (e) retires finished requests, returning
    their pages -- with LIFO preemption (free the youngest's pages,
    requeue it) when the pool runs dry.  See ``serve/scheduler.py`` for
    the policy and ``serve/paged_kv.py`` for the page layout and the
    chunk/page contract.

    Chunked paged prefill: ``prefill_chunk_tokens`` (a multiple of
    ``page_size`` that divides ``max_len``) bounds the prefill tokens
    one engine step may process, so a long-prompt arrival costs a chain
    of chunk-sized steps interleaved with decode instead of stalling
    every running request for a full prefill -- p99 DECODE-step latency
    is bounded by the chunk, not the longest prompt.  ``None`` (the
    default) prefills each admission in one whole-prefix chunk through
    the same code path (the PR 3 monolithic behavior).  The chunk's
    attention context is selected by ``prefill_context``:

      * ``"carry"`` (default): the already-prefilled prefix rides as a
        transient bf16 KV carry, so chunk logits -- and therefore
        temperature-0 tokens -- are BITWISE those of a monolithic
        prefill; the carry is dropped the moment prefill completes.
      * ``"pages"``: the chunk re-reads the prefix from its posit8
        pages (``attention.paged_prefill_blocked`` / the fused kernel
        under ``decode_impl='flash'``): zero extra residency, but the
        context is dequantized, so prompt logits carry quantization
        error and exact static parity is not guaranteed.

    Prefix caching (``prefix_cache=True``): whole prompt-prefix pages
    of completed prefills stay cached in the scheduler's
    ``PrefixIndex`` after retirement; a later request whose prompt
    starts with the same token blocks attaches them READ-ONLY and its
    chunk cursor starts past the match, so it computes -- and the
    admission gate budgets -- only the new pages (see the share/
    copy-on-write contract in ``serve/paged_kv.py``).  This implies
    ``prefill_context="pages"`` (the default under prefix caching):
    the remaining chunks must attend to the prefix THROUGH the shared
    posit8 pages, which hold bitwise the codes a cold run would write
    -- so temperature-0 outputs match a cache-off engine (also on the
    pages context) token for token.  The bf16 carry context cannot
    reproduce a prefix this request never forwarded, so
    ``prefill_context="carry"`` with ``prefix_cache=True`` is
    rejected.

    The KV plane is ALWAYS the posit8 paged pool (that is the point);
    weights pack per ``policy`` exactly like the static engine.  At
    temperature 0 with ``page_size == default_kv_block(max_len)`` of a
    static engine (and ``prefill_context="carry"``), outputs match
    per-request ``ServeEngine.generate`` token for token (the paged and
    contiguous block partitions -- and therefore the online-softmax
    accumulation order -- coincide, and chunked prefill replays the
    monolithic logits bitwise).
    """

    cfg: ModelConfig
    params: Any
    n_pages: int = 64
    page_size: Optional[int] = None
    max_batch: int = 8
    max_len: int = 512
    policy: Optional[PrecisionPolicy] = None
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    prefill_chunk_tokens: Optional[int] = None
    # None resolves to "carry" (bitwise static parity), or to "pages"
    # under prefix_cache (shared pages are only readable through the
    # page table)
    prefill_context: Optional[str] = None
    prefix_cache: bool = False

    def __post_init__(self):
        from ..kernels.flash_decode import default_kv_block
        from .paged_kv import PagedKVPool
        from .scheduler import Scheduler
        if self.cfg.frontend != "none":
            raise ValueError(
                "ContinuousEngine serves token prompts; vision/audio "
                "frontends need per-request frame/patch embeddings the "
                "request queue does not carry")
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        if self.page_size is None:
            self.page_size = default_kv_block(self.max_len)
        if self.max_len % self.page_size:
            rounded = -(-self.max_len // self.page_size) * self.page_size
            raise ValueError(
                f"max_len={self.max_len} must be a multiple of "
                f"page_size={self.page_size}: the page-table row maps "
                f"whole pages, so a partial final page cannot be "
                f"addressed -- round max_len up to {rounded} (what "
                f"launch/serve.py does) or pick a page size that "
                f"divides it")
        self.max_pages_per_req = self.max_len // self.page_size
        if self.prefill_chunk_tokens is not None:
            c = self.prefill_chunk_tokens
            if c <= 0 or c % self.page_size or self.max_len % c:
                raise ValueError(
                    f"prefill_chunk_tokens={c} must be a positive "
                    f"multiple of page_size={self.page_size} that "
                    f"divides max_len={self.max_len} (the chunk/page "
                    f"contract of serve/paged_kv.py)")
        if self.prefill_context is None:
            self.prefill_context = "pages" if self.prefix_cache else "carry"
        if self.prefill_context not in ("carry", "pages"):
            raise ValueError(self.prefill_context)
        if self.prefix_cache and self.prefill_context == "carry":
            raise ValueError(
                "prefix_cache shares posit8 pages a hit request never "
                "forwarded itself, so its chunks can only attend to the "
                "prefix THROUGH the page table: use "
                "prefill_context='pages' (the default when prefix_cache "
                "is set)")
        pool = PagedKVPool(self.cfg, self.n_pages, self.page_size, kv_group)
        self.scheduler = Scheduler(pool, self.max_batch,
                                   max_pages_per_req=self.max_pages_per_req,
                                   prefix_cache=self.prefix_cache)
        # chunk prefill steps: FULL chunk logits (the request's last real
        # token may sit anywhere inside the final chunk)
        self._chunk_step = jax.jit(
            build_prefill_chunk_step(self.cfg, kv_group))
        self._chunk_step_paged = jax.jit(
            build_prefill_chunk_step(self.cfg, kv_group, paged=True),
            donate_argnums=(2,))
        # per-request bf16 KV carries of requests mid-prefill (rid ->
        # {"k","v"} stacked (L,1,T,Kh,Dh)); dropped on completion or
        # preemption.  Bounded by the prefix of the few PREFILLING
        # requests -- the same transient a monolithic prefill held.
        self._prefill_ctx: Dict[int, Any] = {}

        def step(params, tokens, cache):
            # pos operand is dead on the paged path: positions ride in
            # the cache (per request), broadcast over the layer scan
            return zoo.decode_model(params, tokens, self.cfg, cache,
                                    jnp.int32(0))
        self._step = jax.jit(step, donate_argnums=(2,))
        self._key = jax.random.PRNGKey(self.seed)
        self.steps_run = 0
        self.prefill_tokens_computed = 0     # real tokens forwarded (cache
        #                                      hits skip their matched prefix)
        # positions the LAST decode step actually served (requests that
        # retired within the step included) -- the per-step KV-traffic
        # ground truth benchmarks read; [] when the step decoded nothing
        self.last_positions: List[int] = []
        # rids admitted by the LAST step (regression hook: a rid must
        # never show up in scheduler.preempted_log during the same step)
        self.last_admitted: List[int] = []

    @property
    def pool(self):
        return self.scheduler.pool

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its id.  Total length must fit the
        per-request page-table width (``max_len`` slots) -- validated by
        the scheduler, which knows the row width, so a direct scheduler
        user gets the same rejection at the same point."""
        return self.scheduler.submit(
            prompt, max_new_tokens,
            eos_id if eos_id is not None else self.eos_id)

    # -- sampling -----------------------------------------------------------

    def _sample(self, lg: np.ndarray) -> int:
        """One token from one (V,) logit row (greedy at temperature 0,
        matching ``ServeEngine``'s argmax tie-breaking)."""
        if self.temperature <= 0:
            return int(np.argmax(lg))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(
            sub, jnp.asarray(lg) / self.temperature))

    # -- one engine step ----------------------------------------------------

    def _empty_ctx(self):
        hd = self.cfg.resolved_head_dim
        z = jnp.zeros((self.cfg.n_layers, 1, 0, self.cfg.n_kv_heads, hd),
                      jnp.bfloat16)
        return {"k": z, "v": z}

    def _prefill_chunk(self, req) -> int:
        """Run at most ONE prefill chunk for ``req``: allocate the pages
        the chunk's slots land in (lazy, can preempt younger requests),
        forward the chunk against the request's prefilled context, and
        scatter its quantized KV into pages.  Completes prefill (samples
        the first token, PREFILLING -> RUNNING) when the chunk covers
        the prefix's last real token.  Returns the prefill tokens spent
        (the padded chunk width; 0 if ``req`` was preempted before any
        compute)."""
        sched = self.scheduler
        prefix = req.prefix
        ln = prefix.size
        # the cursor starts past the matched shared pages of a prefix-
        # cache hit (page-aligned by construction), so a hit computes
        # only its un-cached remainder
        start = req.prefilled
        if self.prefill_chunk_tokens is None:
            # monolithic: one chunk covering every remaining page slot
            c = self.pool.pages_for(ln) * self.page_size - start
        else:
            c = self.prefill_chunk_tokens
        real = min(c, ln - start)
        if not sched.ensure_prefill_capacity(req, start + real):
            return 0                     # self-preempted: pool too dry
        toks = np.zeros((1, c), np.int32)
        toks[0, :real] = prefix[start:start + real]
        start_arr = jnp.full((1,), start, jnp.int32)
        if self.prefill_context == "pages":
            L = self.cfg.n_layers
            pt = np.zeros((1, self.max_pages_per_req), np.int32)
            pt[0, :len(req.pages)] = req.pages
            cache = self.pool.device_state()
            cache["page_table"] = jnp.tile(jnp.asarray(pt)[None], (L, 1, 1))
            logits, new_cache = self._chunk_step_paged(
                self.params, jnp.asarray(toks), cache, start_arr)
            self.pool.set_device_state(
                {key: new_cache[key] for key in
                 ("k_codes", "v_codes", "k_scale", "v_scale")})
        else:
            ctx = self._prefill_ctx.get(req.rid)
            if start == 0 or ctx is None:
                ctx = self._empty_ctx()
            logits, kv, chunk_q = self._chunk_step(
                self.params, jnp.asarray(toks), ctx, start_arr)
            self.pool.write_chunk(chunk_q, req.pages, start)
            if start + real < ln:        # full chunk: extend the carry
                self._prefill_ctx[req.rid] = {
                    "k": jnp.concatenate([ctx["k"], kv["k"]], axis=2),
                    "v": jnp.concatenate([ctx["v"], kv["v"]], axis=2)}
        req.prefilled = start + real
        self.prefill_tokens_computed += real
        if req.prefilled == ln:
            self._prefill_ctx.pop(req.rid, None)
            nxt = self._sample(np.asarray(logits[0, real - 1]))
            req.generated.append(nxt)
            req.next_token = nxt
            sched.prefill_complete(req)
        return c

    def step(self) -> int:
        """One engine step: capacity for the running batch FIRST, then
        admission, chunked prefill within the token budget, one batched
        decode for everyone running, retirement.  Returns decoded
        request count.

        The ordering is load-bearing: PR 3 admitted (and fully
        prefilled) newcomers BEFORE ensuring capacity for the running
        batch, so under pool pressure the newcomer took the last free
        page, was immediately preempted as the youngest victim, and its
        whole prefill was wasted -- every step while the pressure
        lasted.  Capacity-first means a newcomer is only admitted
        against pages the running batch did not need this step."""
        sched = self.scheduler
        # (1) grow the already-running requests' page tables
        for req in list(sched.running):
            if req.status == RUNNING:    # a victim may drop mid-loop
                sched.ensure_capacity(req)
        # (2) admit against the unclaimed remainder
        self.last_admitted = [r.rid for r in sched.admit()]
        # (3) chunked prefill, oldest first, inside the token budget:
        # at most prefill_chunk_tokens prefill tokens per step (None =
        # whole prefixes, the monolithic behavior)
        budget = self.prefill_chunk_tokens
        spent = 0
        for req in [r for r in sched.running if r.status == PREFILLING]:
            while req.status == PREFILLING and \
                    (budget is None or spent < budget):
                spent += self._prefill_chunk(req)
            if req.status == RUNNING and req.done:
                sched.retire(req)        # budget of 1 / instant EOS
        # drop carries of requests no longer mid-prefill (preempted or
        # completed); they re-prefill from chunk 0 on re-admission
        live = {r.rid for r in sched.running if r.status == PREFILLING}
        for rid in [r for r in self._prefill_ctx if r not in live]:
            del self._prefill_ctx[rid]
        # (4) one batched decode for everyone RUNNING (newly promoted
        # requests may still need the page their first decode write
        # lands in -- their admission gate already reserved budget for
        # it, so this never preempts a same-step admission)
        running = []
        for req in list(sched.running):
            if req.status == RUNNING and sched.ensure_capacity(req):
                running.append(req)
        self.last_positions = [req.position for req in running]
        if not running:
            return 0
        b, npp = self.max_batch, self.max_pages_per_req
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        page_table = np.zeros((b, npp), np.int32)   # pad rows park on page 0
        for row, req in enumerate(running):
            tokens[row, 0] = req.next_token
            positions[row] = req.position
            page_table[row, :len(req.pages)] = req.pages
        L = self.cfg.n_layers
        cache = self.pool.device_state()
        cache["page_table"] = jnp.tile(
            jnp.asarray(page_table)[None], (L, 1, 1))
        cache["positions"] = jnp.tile(jnp.asarray(positions)[None], (L, 1))
        logits, new_cache = self._step(self.params, jnp.asarray(tokens),
                                       cache)
        self.pool.set_device_state(new_cache)
        lg = np.asarray(logits[:, 0].astype(jnp.float32))
        for row, req in enumerate(running):
            nxt = self._sample(lg[row])
            req.generated.append(nxt)
            req.next_token = nxt
            if req.done:
                sched.retire(req)
        self.steps_run += 1
        return len(running)

    # -- counters -----------------------------------------------------------

    def reset_counters(self) -> None:
        """Zero every run counter (bench warm-up hygiene: a warm request
        must not leak its pages/steps/preemptions into the measured
        run).  The pool's CURRENT allocation -- e.g. prefix pages the
        warm-up left cached -- becomes the new peak baseline."""
        self.steps_run = 0
        self.prefill_tokens_computed = 0
        self.pool.alloc_peak = self.pool.used_pages
        sched = self.scheduler
        sched.preemption_count = 0
        sched.prefill_preemptions = 0
        sched.wasted_prefill_tokens = 0
        sched.preempted_log.clear()
        sched.retired_log.clear()
        if sched.prefix is not None:
            sched.prefix.hits = 0
            sched.prefix.hit_tokens = 0
            sched.prefix.evictions = 0

    # -- drive to completion ------------------------------------------------

    def run(self, max_steps: int = 100000) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: prompt+generated}.  Admission can always make progress
        when nothing is running (all pages are free then), so the step
        bound only guards against bugs."""
        steps = 0
        while self.scheduler.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("continuous engine failed to drain")
        return {rid: req.output
                for rid, req in self.scheduler.finished.items()}
