"""Batched serving engine: prefill -> decode with the packed weight plane.

This is the runtime the decode_* and long_* dry-run shapes lower:
``serve_step`` is one new token against a seq_len KV cache (or SSM state).
Weights can be physically packed (PackedTensor leaves -- HBM holds the
low-bit codes, the paper's memory-bandwidth reduction) and the KV cache
can be Posit(8,0)-quantized end-to-end (``quantized_kv=True``): prefill
returns codes+scales (one-shot ``zoo.quantize_cache`` fused into the
prefill jit, before ``_pad_cache``), decode writes the quantized layout
incrementally and reads only the live prefix of it per step (the
length-aware paths in ``models/attention``) -- the bf16 cache never
exists in HBM.

The engine itself does simple static batching with per-request lengths
masked by position -- enough to serve real batched traffic in the
examples while keeping the step function identical to the dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, RunConfig
from ..core.policy import PrecisionPolicy
from ..models import zoo

__all__ = ["build_prefill_step", "build_serve_step", "ServeEngine"]


def build_prefill_step(cfg: ModelConfig, last_logit_only: bool = False,
                       quantized_kv: bool = False,
                       kv_group: Optional[int] = None):
    """(params, batch) -> (logits, cache): full-sequence forward that also
    materializes the KV cache / SSM state.

    ``last_logit_only``: return logits only for the final position -- the
    only one generation needs.  XLA pushes the slice up through the
    readout matmul, eliminating ~(S-1)/S of lm_head FLOPs and the
    (B, S, vocab) buffer (a §Perf hillclimb lever for prefill cells).

    ``quantized_kv``: quantize the returned KV cache to posit8 codes +
    ``kv_group``-grouped scales inside the same jit (XLA fuses the
    quantize into the cache write, so the bf16 cache is a transient,
    not an output)."""

    def prefill(params, batch):
        logits, cache, _ = zoo.apply_model(params, batch, cfg, mode="prefill",
                                           cache=None)
        if last_logit_only:
            logits = logits[:, -1:]
        if quantized_kv:
            cache = zoo.quantize_cache(cache, kv_group)
        return logits, cache

    return prefill


def build_serve_step(cfg: ModelConfig):
    """(params, tokens (B,1), cache, pos) -> (logits, new_cache)."""

    def serve_step(params, tokens, cache, pos):
        return zoo.decode_model(params, tokens, cfg, cache, pos)

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Static-batch serving with greedy/temperature sampling."""

    cfg: ModelConfig
    params: Any
    max_len: int = 2048
    # posit8 KV cache end-to-end: prefill returns codes+scales, decode
    # reads only the live prefix of them per step.  The scale grouping
    # follows ``policy.group_size`` (the weight plane's grid).
    quantized_kv: bool = False
    policy: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        self._prefill = jax.jit(build_prefill_step(
            self.cfg, last_logit_only=True,
            quantized_kv=self.quantized_kv, kv_group=kv_group))
        self._step = jax.jit(build_serve_step(self.cfg))

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0, key=None) -> np.ndarray:
        """tokens: (B, S0) prompt -> (B, S0+steps) completed."""
        b, s0 = tokens.shape
        batch = {"tokens": tokens}
        # prefill is unconditional for every model family: it returns the
        # populated KV cache / SSM state (already posit8 codes+scales
        # under quantized_kv) that decode continues from.
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, b)
        out = [np.asarray(tokens)]
        last = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(steps):
            out.append(np.asarray(last))
            logits, cache = self._step(self.params, last,
                                       cache, jnp.int32(s0 + i))
            lg = logits[:, -1]
            if temperature > 0:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(
                    sub, lg / temperature)[:, None].astype(jnp.int32)
            else:
                last = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)

    # cache leaves with a sequence axis, all laid out (L, B, S, H, ...):
    # bf16 k/v, posit8 codes, and their (..., Gs) scale tensors
    _SEQ_KEYS = frozenset(
        {"k", "v", "k_codes", "v_codes", "k_scale", "v_scale"})

    def _pad_cache(self, cache, b):
        """Grow prefill-length KV buffers to max_len for decode.

        Structure-aware: pads by cache KEY (the seq axis is always axis 2
        of the stacked (L, B, S, H, ...) layout) instead of guessing from
        ndim/shape/dtype -- scale tensors pad on the right rank and SSM /
        RWKV states (no seq axis, no KV keys) pass through untouched."""
        def pad(key, x):
            if key in self._SEQ_KEYS and x.shape[2] < self.max_len:
                pad_width = [(0, 0)] * x.ndim
                pad_width[2] = (0, self.max_len - x.shape[2])
                return jnp.pad(x, pad_width)
            return x

        def rec(node):
            if isinstance(node, dict):
                return {key: (rec(val) if isinstance(val, dict)
                              else pad(key, val))
                        for key, val in node.items()}
            return node

        return rec(cache)
