"""Disaggregated prefill/decode serving over a posit8 page handoff.

The interleaved ``ContinuousEngine`` time-slices ONE device program
between two workloads with opposite rooflines: prefill (compute-bound
-- big matmuls over whole chunks) and decode (memory-bound -- one token
per request against the paged KV pool).  Even with chunked prefill
bounding the stall, every prefill chunk still sits INSIDE the decode
step's critical path: a long-prompt arrival inflates decode p99 by a
chunk forward pass.

This module splits the engine along that roofline boundary:

  ``PrefillWorker``   owns its own posit8 page pool + the PR 4 chunk-
                      budget admitter (admission, chunk pacing, prefix-
                      cache hits, mid-prefill preemption).  When a
                      request's prefill completes, its pages are
                      EXPORTED -- posit8 codes + po2 group scales, the
                      wire format IS the pool format -- and the request
                      parks until the handoff channel has room.
  ``PageHandoffChannel``
                      a depth-bounded (default 2: double-buffered)
                      queue of ``(request, payload)`` pairs.  The
                      payload is the gathered page leaves -- ~4x
                      smaller than a bf16 KV handoff
                      (``paged_kv.page_handoff_bytes`` is the exact
                      per-page model) -- plus, for recurrent families,
                      the request's quantized state slab
                      (``export_state``), which crosses bitwise and
                      makes the handoff exact for SSM/RWKV/hybrid
                      requests too -- optionally ``device_put`` to
                      the decode worker's device slice so the copy
                      overlaps whatever both workers are computing.
  ``DecodeWorker``    owns its own pool + the K-step device-resident
                      decode loop of PR 6, running UNINTERRUPTED: no
                      prefill chunk ever executes between its
                      dispatches.  Imported pages scatter bitwise into
                      its pool; the ``DecodeRunner`` keeps the mapping-
                      epoch protocol, so the page table stays cached
                      across handoffs that do not change the batch.

``DisaggEngine.step`` overlaps the two: the decode dispatch is launched
FIRST (JAX dispatch is async -- the jitted loop runs on device while
host code continues), the prefill worker then runs a full admit/chunk/
handoff step, and only afterwards does the engine sync the decode
dispatch's (B, K) token buffer.  Prefill chunks for request A hide
behind decode iterations for requests B..Z; ``last_decode_step_s``
times ONLY the dispatch+sync halves, which is the decode-latency
isolation the split buys (bench_serve's ``disagg`` scenario asserts
its p99 against the interleaved engine's).

Backpressure is structural, not configured: a completed prefill parks
holding its prefill pages AND its admitter batch slot until the channel
drains, a full channel blocks further exports, and a handoff stays
queued until the decode pool can allocate its pages.  When the decode
pool runs dry mid-decode the runner BOUNCES its youngest request --
pages freed, request handed back to the admitter's queue FRONT
(``Scheduler.reaccept``), where it re-prefills prompt+generated and
re-crosses the channel: the disaggregated analogue of LIFO preemption.
``submit`` rejects requests whose total footprint cannot fit the decode
pool, so a lone bounced request always fits on retry (no livelock).

PARITY: at temperature 0 the disaggregated engine's outputs are
token-for-token those of the interleaved ``ContinuousEngine`` (same
chunk code via ``_ChunkPrefillMixin``, same dispatch/replay code via
``_dispatch_decode_loop``/``_apply_decode_tokens``, bitwise page
export/import) and -- on the carry prefill context -- of per-request
static ``ServeEngine.generate``, including across mid-prefill
preemption and prefix-cache hits.  ``tests/test_disagg.py`` pins all
three leg pairs.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.policy import PrecisionPolicy
from ..models import zoo
from ..obs import MetricRegistry, NULL_RECORDER, bind_counters
from .engine import (_build_decode_loop, _ChunkPrefillMixin,
                     _apply_decode_tokens, _decode_horizon,
                     _device_only, _dispatch_decode_loop, _PageTableCache,
                     _trace_counted, build_prefill_chunk_step)
from .paged_kv import PagedKVPool
from .scheduler import RUNNING, DecodeRunner, Request, Scheduler

__all__ = ["PageHandoffChannel", "PrefillWorker", "DecodeWorker",
           "DisaggEngine"]

_NULL_CTX = contextlib.nullcontext()


class PageHandoffChannel:
    """Depth-bounded queue of completed prefills crossing from the
    prefill worker to the decode worker.

    Each entry is ``(request, payload)`` where the payload is the
    request's gathered pool leaves (posit8 codes + bf16 po2 scales,
    ``PagedKVPool.export_pages``) -- the handoff moves the COMPRESSED
    cache, never a bf16 one.  Attention-only families push the flat
    page-leaf dict; stateful families push the nested
    ``{"state": export_state(slab)[, "kv": export_pages(pages)]}``
    form, so a recurrent request's whole footprint -- its one slab,
    plus KV pages for hybrids -- crosses in one entry and imports
    bitwise.  ``depth`` bounds the prefills in flight
    (default 2: the decode side imports one buffer while the prefill
    side fills the next); a full channel parks further completions on
    the prefill side, holding their pages and batch slots -- the
    backpressure that keeps the admitter from racing ahead of decode.

    With ``device`` set, ``push`` copies the payload to the decode
    worker's device slice immediately, so the transfer overlaps both
    workers' compute instead of serializing into the import."""

    _COUNTERS = ("handoffs",        # payloads pushed
                 "handoff_pages",   # pages moved
                 "handoff_bytes")   # device bytes moved (sum of .nbytes)

    def __init__(self, depth: int = 2, device=None,
                 registry: Optional[MetricRegistry] = None,
                 trace=None, namespace: str = "channel"):
        assert depth >= 1, depth
        self.depth = int(depth)
        self.device = device
        self._q: Deque[Tuple[Request, Dict[str, jax.Array]]] = deque()
        self.metrics = registry if registry is not None else MetricRegistry()
        self._trace = trace if trace is not None else NULL_RECORDER
        bind_counters(self, self.metrics, namespace)

    def reset_counters(self) -> None:
        for c in self._COUNTERS:
            setattr(self, c, 0)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.depth

    def push(self, req: Request, payload: Dict[str, jax.Array]) -> None:
        assert not self.full, "push on a full channel (check .full first)"
        rid = getattr(req, "rid", None)
        with self._trace.span("channel_push", rid=rid):
            if self.device is not None:
                payload = jax.tree.map(
                    lambda val: jax.device_put(val, self.device), payload)
        kv = payload.get("kv") if "state" in payload else payload
        pages = int(kv["k_codes"].shape[1]) if kv is not None else 0
        nbytes = sum(int(leaf.nbytes) for leaf in jax.tree.leaves(payload))
        self.handoffs += 1
        self.handoff_pages += pages
        self.handoff_bytes += nbytes
        self._trace.event("HANDOFF", rid=rid, pages=pages, bytes=nbytes)
        self._q.append((req, payload))

    def peek(self) -> Tuple[Request, Dict[str, jax.Array]]:
        return self._q[0]

    def pop(self) -> Tuple[Request, Dict[str, jax.Array]]:
        return self._q.popleft()


class PrefillWorker(_ChunkPrefillMixin):
    """The prefill half: PR 4's chunk-budget admitter over its own
    posit8 pool, exporting completed prefills into the handoff channel.

    Runs the EXACT interleaved chunk code (``_ChunkPrefillMixin``):
    admission, lazy page claims, carry/pages contexts, prefix-cache
    hits and mid-prefill preemption all behave as they do in
    ``ContinuousEngine`` -- that shared implementation is the parity
    argument's first half.  A completed prefill (first token sampled,
    PREFILLING -> RUNNING) parks on ``_ready`` until the channel has
    room; parked requests still hold their pages and admitter slots
    (structural backpressure) and remain legal preemption victims -- a
    preempted parked request simply drops off ``_ready`` and
    re-completes after its re-prefill, like any RUNNING victim."""

    _COUNTERS = ("prefill_tokens_computed",)

    def __init__(self, cfg: ModelConfig, params: Any, n_pages: int,
                 page_size: int, max_batch: int, max_pages_per_req: int,
                 kv_group: Optional[int], temperature: float, base_key,
                 prefill_chunk_tokens: Optional[int], prefill_context: str,
                 prefix_cache: bool, device=None,
                 registry: Optional[MetricRegistry] = None, trace=None):
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_pages_per_req = max_pages_per_req
        self.temperature = temperature
        self._base_key = base_key
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.prefill_context = prefill_context
        self.metrics = registry if registry is not None else MetricRegistry()
        self._trace = trace if trace is not None else NULL_RECORDER
        n_slabs = max_batch \
            if "state" in PagedKVPool.page_kinds(cfg) else 0
        pool = PagedKVPool(cfg, n_pages, page_size, kv_group,
                           n_slabs=n_slabs)
        if device is not None:
            pool.set_device_state(jax.tree.map(
                lambda leaf: jax.device_put(leaf, device),
                pool.device_state()))
        pool.register_gauges(self.metrics, "prefill/pool")
        self.scheduler = Scheduler(pool, max_batch,
                                   max_pages_per_req=max_pages_per_req,
                                   prefix_cache=prefix_cache,
                                   registry=self.metrics, trace=self._trace,
                                   namespace="prefill/scheduler")
        self.trace_counts: Dict[str, int] = {}
        self._chunk_step = jax.jit(_trace_counted(
            build_prefill_chunk_step(cfg, kv_group),
            self.trace_counts, "prefill_chunk"))
        # the paged context re-reads the prefix through the page table,
        # which stateful families cannot do (their context is the
        # recurrent state) -- DisaggEngine rejects that combination, so
        # only build the paged step when it will actually be called
        self._chunk_step_paged = None
        if prefill_context == "pages":
            self._chunk_step_paged = jax.jit(_trace_counted(
                build_prefill_chunk_step(cfg, kv_group, paged=True),
                self.trace_counts, "prefill_chunk_paged"),
                donate_argnums=(2,))
        self._prefill_ctx: Dict[int, Any] = {}
        self._ready: List[Request] = []       # completed, awaiting channel
        bind_counters(self, self.metrics, "prefill")

    @property
    def pool(self) -> PagedKVPool:
        return self.scheduler.pool

    def reset_counters(self) -> None:
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.pool.alloc_peak = self.pool.used_pages
        self.scheduler.reset_counters()

    def _drain_ready(self, channel: PageHandoffChannel) -> int:
        """Export parked completions into the channel, oldest first,
        until it fills.  Export before release: ``export_pages`` /
        ``export_state`` are pure functional gathers, so the payload
        stays valid after the source pages (and slab) return to the
        free lists (prefix-shared pages just decref back to the
        index).  Stateful families export the nested form the channel
        and decode worker understand: the request's slab, plus its KV
        pages for hybrids."""
        sent = 0
        while self._ready:
            req = self._ready[0]
            if req.status != RUNNING:
                # preempted while parked: the admitter already freed its
                # pages and requeued it; it re-parks after re-prefill
                self._ready.pop(0)
                continue
            if channel.full:
                break
            if self.pool.has_state:
                payload: Dict = {"state": self.pool.export_state(req.slab)}
                if req.pages:
                    payload["kv"] = self.pool.export_pages(req.pages)
            else:
                payload = self.pool.export_pages(req.pages)
            self.scheduler.release(req)
            channel.push(req, payload)
            self._ready.pop(0)
            sent += 1
        return sent

    def step(self, channel: PageHandoffChannel) -> int:
        """One prefill-side step: drain parked completions (channel
        room may have opened since last step), admit, run the chunk
        budget, park/retire this step's completions, drain again.
        Returns handoffs pushed."""
        sent = self._drain_ready(channel)
        for req in self.scheduler.admit():
            if req.status == RUNNING:
                # a resumed preemption/bounce snapshot: its state (+ KV)
                # just imported bitwise, nothing to prefill -- park it
                # for re-handoff straight away
                self._ready.append(req)
        for req in self._prefill_phase():
            if req.done:
                # budget of 1 / instant EOS: never needs a decode side
                self.scheduler.retire(req)
            else:
                self._ready.append(req)
        return sent + self._drain_ready(channel)


class DecodeWorker:
    """The decode half: PR 6's K-step device-resident loop over its own
    posit8 pool, fed exclusively by imported page handoffs.

    ``dispatch``/``sync`` are split so the engine can overlap host work
    with the device scan: ``dispatch`` launches the jitted loop (async)
    and returns the in-flight record; ``sync`` blocks on the (B, K)
    token buffer and replays the done-logic.  Both run the SAME
    ``_dispatch_decode_loop``/``_apply_decode_tokens`` code as the
    interleaved engine -- the parity argument's second half."""

    _COUNTERS = ("decode_dispatches",   # jitted decode-loop calls
                 "page_table_uploads",  # (B, NP) host->device uploads
                 "logits_host_bytes",   # stays 0: sampling is fused
                 "token_host_bytes")    # device->host sampled-token sync

    def __init__(self, cfg: ModelConfig, params: Any, n_pages: int,
                 page_size: int, max_batch: int, max_pages_per_req: int,
                 kv_group: Optional[int], temperature: float, base_key,
                 decode_steps: int, device=None,
                 registry: Optional[MetricRegistry] = None, trace=None,
                 annotation=None):
        self.params = params
        self.max_batch = max_batch
        self.max_pages_per_req = max_pages_per_req
        self.decode_steps = decode_steps
        self._base_key = base_key
        self.metrics = registry if registry is not None else MetricRegistry()
        self._trace = trace if trace is not None else NULL_RECORDER
        self._annotation = annotation
        n_slabs = max_batch \
            if "state" in PagedKVPool.page_kinds(cfg) else 0
        pool = PagedKVPool(cfg, n_pages, page_size, kv_group,
                           n_slabs=n_slabs)
        if device is not None:
            pool.set_device_state(jax.tree.map(
                lambda leaf: jax.device_put(leaf, device),
                pool.device_state()))
        pool.register_gauges(self.metrics, "decode/pool")
        self.runner = DecodeRunner(pool, max_batch,
                                   registry=self.metrics, trace=self._trace,
                                   namespace="decode/runner")
        # compile-count sentinel + transfer-guard hook: same contract
        # as ContinuousEngine (see engine._trace_counted/_device_only);
        # benches flip ``transfer_guard`` on after warm-up
        self.trace_counts: Dict[str, int] = {}
        self.transfer_guard = False
        self._decode_loop = jax.jit(_trace_counted(
            _build_decode_loop(cfg, temperature, decode_steps),
            self.trace_counts, "decode_loop"),
            donate_argnums=(3,))
        self._pt_cache = _PageTableCache()
        self.last_positions: List[int] = []
        bind_counters(self, self.metrics, "decode")

    @property
    def pool(self) -> PagedKVPool:
        return self.runner.pool

    def reset_counters(self) -> None:
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.pool.alloc_peak = self.pool.used_pages
        self.runner.reset_counters()

    def admit_handoffs(self, channel: PageHandoffChannel) -> int:
        """Import queued handoffs while a batch slot AND pool pages are
        available.  A handoff the pool cannot place stays queued (the
        channel is the buffer) -- head-of-line blocking here is the
        deliberate backpressure that eventually parks the prefill side
        rather than thrashing decode with bounces."""
        took = 0
        while len(channel) and self.runner.has_slot:
            req, payload = channel.peek()
            nested = "state" in payload
            kv = payload.get("kv") if nested else payload
            n = int(kv["k_codes"].shape[1]) if kv is not None else 0
            pages = self.pool.alloc(n) if n else []
            if pages is None:
                break                     # decode pool dry: retry next step
            slab = None
            if nested:
                slab = self.pool.alloc_slab()
                if slab is None:          # state plane dry: roll back
                    if pages:
                        self.pool.free(pages)
                    break
            with self._trace.span("channel_pull", rid=req.rid):
                if kv is not None:
                    self.pool.import_pages(kv, pages)
                if nested:
                    self.pool.import_state(payload["state"], slab)
            self.runner.accept(req, pages, slab)
            channel.pop()
            took += 1
        return took

    def dispatch(self):
        """Launch one K-step decode dispatch for everyone running (after
        pre-claiming each request's decode window, bouncing the youngest
        on pool exhaustion).  Returns the in-flight dispatch record, or
        None if nothing decoded."""
        runner = self.runner
        running = []
        for req in list(runner.running):
            if req.status == RUNNING and runner.ensure_capacity(
                    req, horizon=_decode_horizon(req, self.decode_steps)):
                running.append(req)
        self.last_positions = [req.position for req in running]
        if not running:
            return None
        ann = self._annotation("decode_dispatch") \
            if self._annotation is not None else _NULL_CTX
        with ann, _device_only(self.transfer_guard):
            disp = _dispatch_decode_loop(
                self._decode_loop, self.params, self.pool, running,
                self.max_batch, self._pt_cache, runner.epoch,
                self.max_pages_per_req, self._base_key)
        self.decode_dispatches += 1
        self.page_table_uploads += disp["uploaded"]
        self._trace.event("DECODE_DISPATCH", batch=len(running),
                          k=self.decode_steps, uploaded=disp["uploaded"])
        return disp

    def sync(self, disp) -> int:
        """Block on a dispatch's (B, K) token buffer and replay the
        device done-logic; retires finished requests to the runner.
        Returns decoded request count."""
        if disp is None:
            return 0
        with _device_only(self.transfer_guard):
            # the ONE sanctioned (B, K) host sync of the decode side
            toks = jax.device_get(disp["toks_dev"])
        self.token_host_bytes += toks.nbytes
        self._trace.event("DECODE_SYNC", token_bytes=toks.nbytes)
        return _apply_decode_tokens(disp, toks, self.runner.retire)


@dataclasses.dataclass
class DisaggEngine:
    """Disaggregated prefill/decode serving engine (see module doc).

    Drop-in for ``ContinuousEngine`` at the submit/step/run level; the
    pool splits into ``prefill_pages`` + ``decode_pages`` (two pools,
    two device programs) and ``channel_depth`` bounds the prefills in
    flight across the handoff.  ``last_decode_step_s`` is the previous
    step's decode-side wall time EXCLUDING the overlapped prefill work
    -- the isolation metric the split exists for."""

    cfg: ModelConfig
    params: Any
    prefill_pages: int = 64
    decode_pages: int = 64
    page_size: Optional[int] = None
    max_batch: int = 8
    max_len: int = 512
    policy: Optional[PrecisionPolicy] = None
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    prefill_chunk_tokens: Optional[int] = None
    prefill_context: Optional[str] = None
    prefix_cache: bool = False
    decode_steps: int = 1
    channel_depth: int = 2
    # distinct device slices for the two workers (parallel/sharding.py
    # ``split_devices``); None/None runs both programs on the default
    # device -- the dispatch-async overlap still applies
    prefill_device: Any = None
    decode_device: Any = None
    # observability (docs/observability.md): see ``ContinuousEngine``
    trace: Any = None
    profile_annotations: bool = False

    _COUNTERS = ("steps_run",)

    def __post_init__(self):
        from ..kernels.flash_decode import default_kv_block
        if self.cfg.frontend != "none":
            raise ValueError(
                "DisaggEngine serves token prompts; vision/audio "
                "frontends need per-request frame/patch embeddings the "
                "request queue does not carry")
        if self.policy is not None:
            self.params = zoo.pack_params(self.params, self.policy)
        kv_group = self.policy.group_size if self.policy else None
        if self.page_size is None:
            self.page_size = default_kv_block(self.max_len)
        if self.max_len % self.page_size:
            rounded = -(-self.max_len // self.page_size) * self.page_size
            raise ValueError(
                f"max_len={self.max_len} must be a multiple of "
                f"page_size={self.page_size} (round up to {rounded})")
        self.max_pages_per_req = self.max_len // self.page_size
        if self.prefill_chunk_tokens is not None:
            c = self.prefill_chunk_tokens
            if c <= 0 or c % self.page_size or self.max_len % c:
                raise ValueError(
                    f"prefill_chunk_tokens={c} must be a positive "
                    f"multiple of page_size={self.page_size} that "
                    f"divides max_len={self.max_len}")
        if self.prefill_context is None:
            self.prefill_context = "pages" if self.prefix_cache else "carry"
        if self.prefill_context not in ("carry", "pages"):
            raise ValueError(self.prefill_context)
        if "state" in PagedKVPool.page_kinds(self.cfg) \
                and self.prefill_context == "pages":
            raise ValueError(
                f"family {self.cfg.family!r} carries recurrent state, "
                f"which never lands in pages and cannot be re-read "
                f"through a page table: serve it with "
                f"prefill_context='carry' (which also rules out "
                f"prefix_cache -- a cached prefix cannot reproduce the "
                f"state of tokens this request never forwarded)")
        if self.prefix_cache and self.prefill_context == "carry":
            raise ValueError(
                "prefix_cache needs prefill_context='pages' (shared "
                "posit8 pages are only readable through the page table)")
        if self.decode_steps < 1:
            raise ValueError(
                f"decode_steps={self.decode_steps} must be >= 1")
        base_key = jax.random.PRNGKey(self.seed)
        # one registry + recorder spans the engine and both workers
        self.metrics = MetricRegistry()
        self._trace = self.trace if self.trace is not None else NULL_RECORDER
        if self._trace.enabled and self._trace.hist_registry is None:
            self._trace.hist_registry = self.metrics
        bind_counters(self, self.metrics, "engine")
        annotation = None
        if self.profile_annotations:
            from jax.profiler import TraceAnnotation
            annotation = TraceAnnotation
        params_p = self.params if self.prefill_device is None else \
            jax.device_put(self.params, self.prefill_device)
        params_d = self.params if self.decode_device is None else \
            jax.device_put(self.params, self.decode_device)
        self.prefill = PrefillWorker(
            self.cfg, params_p, self.prefill_pages, self.page_size,
            self.max_batch, self.max_pages_per_req, kv_group,
            self.temperature, base_key, self.prefill_chunk_tokens,
            self.prefill_context, self.prefix_cache,
            device=self.prefill_device,
            registry=self.metrics, trace=self._trace)
        self.decode = DecodeWorker(
            self.cfg, params_d, self.decode_pages, self.page_size,
            self.max_batch, self.max_pages_per_req, kv_group,
            self.temperature, base_key, self.decode_steps,
            device=self.decode_device,
            registry=self.metrics, trace=self._trace,
            annotation=annotation)
        self.channel = PageHandoffChannel(self.channel_depth,
                                          device=self.decode_device,
                                          registry=self.metrics,
                                          trace=self._trace)
        # decode-side critical path (dispatch + sync, prefill hidden):
        # the per-step sample behind ``last_decode_step_s``
        self._step_hist = self.metrics.histogram("engine/decode_step_ms")
        self.last_decode_step_s = 0.0

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its id.  Beyond the admitter's own
        checks, the request's TOTAL footprint must fit the decode pool
        alone: a bounced request retries against an otherwise-empty
        decode side, so this is the no-livelock guarantee (the prefill
        pool is checked by the admitter as usual)."""
        prompt_arr = np.asarray(prompt, np.int32).reshape(-1)
        need = self.decode.pool.pages_for(
            prompt_arr.size + int(max_new_tokens))
        if need > self.decode.pool.n_pages:
            raise ValueError(
                f"request needs {need} pages but the decode pool only "
                f"has {self.decode.pool.n_pages}: raise decode_pages or "
                f"shorten the request")
        return self.prefill.scheduler.submit(
            prompt_arr, max_new_tokens,
            eos_id if eos_id is not None else self.eos_id)

    # -- one engine step ----------------------------------------------------

    def step(self) -> int:
        """One disaggregated step.  Order is the overlap:

          1. import queued handoffs (cheap scatter, must land before the
             dispatch so a new arrival decodes this step),
          2. LAUNCH the decode dispatch -- async, device starts the
             K-step scan,
          3. hand bounced decode requests back to the admitter,
          4. run a whole prefill-side step (admit / chunks / handoff)
             WHILE the decode scan runs,
          5. sync the dispatch's token buffer and retire.

        ``last_decode_step_s`` sums only (2) and (5): the decode
        critical path with prefill hidden behind it.  Returns decoded
        request count."""
        tr = self._trace
        with tr.span("step"):
            with tr.span("admit"):
                self.decode.admit_handoffs(self.channel)
            t0 = time.perf_counter()
            with tr.span("decode_dispatch"):
                disp = self.decode.dispatch()
            t1 = time.perf_counter()
            for req in self.decode.runner.drain_bounced():
                self.prefill.scheduler.reaccept(req)
            with tr.span("prefill"):
                self.prefill.step(self.channel)
            t2 = time.perf_counter()
            with tr.span("decode_sync"):
                n = self.decode.sync(disp)
            t3 = time.perf_counter()
            self.last_decode_step_s = (t1 - t0) + (t3 - t2)
            self._step_hist.observe(self.last_decode_step_s * 1e3)
            self.steps_run += 1
            return n

    # -- aggregate views ----------------------------------------------------

    @property
    def finished(self) -> Dict[int, Request]:
        """rid -> finished request, across both sides (instant-done
        requests retire on the prefill side and never cross)."""
        return {**self.prefill.scheduler.finished,
                **self.decode.runner.finished}

    @property
    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_work or len(self.channel) > 0
                or bool(self.decode.runner.running))

    @property
    def prefill_tokens_computed(self) -> int:
        return self.prefill.prefill_tokens_computed

    @property
    def decode_dispatches(self) -> int:
        return self.decode.decode_dispatches

    @property
    def page_table_uploads(self) -> int:
        return self.decode.page_table_uploads

    @property
    def logits_host_bytes(self) -> int:
        return self.decode.logits_host_bytes

    @property
    def token_host_bytes(self) -> int:
        return self.decode.token_host_bytes

    @property
    def handoffs(self) -> int:
        return self.channel.handoffs

    @property
    def handoff_pages(self) -> int:
        return self.channel.handoff_pages

    @property
    def handoff_bytes(self) -> int:
        return self.channel.handoff_bytes

    @property
    def decode_bounces(self) -> int:
        return self.decode.runner.bounce_count

    # -- counters -----------------------------------------------------------

    def reset_counters(self) -> None:
        """Zero every run counter on every layer (engine, both workers,
        their scheduler/runner, the channel) -- each layer zeroes its
        OWN ``_COUNTERS`` registry."""
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.last_decode_step_s = 0.0
        self.prefill.reset_counters()
        self.decode.reset_counters()
        self.channel.reset_counters()
        # registry-wide sweep: clears span/SLO histograms too (callback
        # gauges are live reads and have nothing to reset)
        self.metrics.reset()

    # -- drive to completion ------------------------------------------------

    def run(self, max_steps: int = 100000) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: prompt+generated}."""
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("disaggregated engine failed to drain")
        return {rid: req.output for rid, req in self.finished.items()}
