"""Paged posit8 KV pool: the physical cache plane of continuous batching.

The static engine's contiguous cache charges every request worst-case
``max_len`` KV memory up front.  The pool instead holds one shared set
of fixed-size PAGES per layer -- posit8 codes + po2 group scales, the
same unified ``quant.group_scales`` layout as the contiguous quantized
cache -- and each request owns an ordered list of page ids (its page
table).  A request's KV footprint is ceil(live_tokens / page) pages, so
pool capacity is spent on LIVE tokens, and admission/preemption decide
who gets pages when they run out.

Layout (page size == the decode kernel's KV block, so paged and
contiguous decode share one block partition and agree bitwise):

  k_codes/v_codes : (L, P, page, Kh, Dh) uint8
  k_scale/v_scale : (L, P, page, Kh, Gs) bf16, Gs = Dh/group

A page id indexes every layer's pool simultaneously (one allocation
covers all L layers).  Page 0 is the PARKING page: never allocated,
never read through a live mask -- padded batch rows in the fixed-shape
decode step write their garbage there, and page-table rows are padded
with it so dead gathers stay in bounds.

Alloc/free is host-side (a free list, LIFO for locality, backed by an
allocated-page set so the double-free guard is O(1) per page); the
device arrays move only through ``write_prefill``/``write_chunk``
(batched scatter of a quantized prefill cache / chunk into pages), the
in-jit chunk scatter of paged chunk prefill
(``attention._attn_prefill_paged``) and the decode step itself (the
per-token scatter in ``attention._attn_decode_paged``).

Chunk/page contract (chunked paged prefill)
-------------------------------------------
Prefill proceeds in fixed-size CHUNKS that are whole pages:
``chunk == k * page_size`` and chunks start at page boundaries, so a
chunk's tokens land in ``chunk / page_size`` consecutive page-table
slots and ``write_chunk`` is a pure page scatter -- no page is ever
written by two different chunks, and a half-prefilled request can be
preempted by freeing its pages with no partial-page state to unwind.
The engine additionally requires ``chunk | max_len`` so the last chunk
of a ``max_len``-long prefix never indexes past the page table.  The
final chunk of a prefix may cover fewer real tokens than ``chunk``;
its pad slots scatter garbage that decode never reads (the live mask
is positional), exactly like the monolithic prefill bucket did.

Share / refcount / copy-on-write contract (prefix caching)
----------------------------------------------------------
A page may appear in MORE than one request's page table: the
scheduler's prefix index shares whole PROMPT-prefix pages between
requests with a common preamble.  The pool therefore counts references
per page -- ``alloc`` hands out pages at refcount 1, ``incref`` adds a
holder (a sharing request, or the prefix index itself), and ``free`` is
a DECREF: a page only returns to the free list when its last holder
drops it.  The old ``_allocated``-set invariants become refcount
invariants -- ``_allocated`` is exactly the pages with refcount >= 1,
and decref of an unallocated page is the double-free bug it always was.

The copy-on-write discipline is that only whole prompt-prefix pages
are ever shared, and shared pages are READ-ONLY by construction rather
than by trap: the prefix match is capped so the page holding the
prompt's LAST token is always recomputed privately, a hit request's
chunk cursor starts past the matched pages (so the chunk-prefill
scatter of ``attention._attn_prefill_paged`` / ``write_chunk`` only
ever lands in its private pages), and the decode scatter of
``attention._attn_decode_paged`` writes at ``position >= len(prompt)``
-- past every shared slot.  No write path can reach a shared page, so
sharing needs no copy and the pages reproduce the cold path's KV
bitwise (same tokens, same params, same chunk computation).

Page KINDS: growable KV pages vs fixed-size state SLABS
-------------------------------------------------------
The pool stores up to two kinds of physical cache, decided by the
config's layer kinds (``page_kinds``):

  "kv"    -- attention layers.  Growable: a request's footprint is
             ceil(live_tokens / page) pages and climbs as it decodes.
  "state" -- recurrent (mamba / rwkv) layers.  FIXED: one slab per
             request holds the whole quantized state pytree (posit8
             codes + bf16 group scales per leaf -- conv boundary,
             scan state, token-shift carries), and a decode step
             rewrites it in place.  No growth, no lazy allocation:
             admission budgets exactly one slab for the request's
             entire lifetime.

Slab buffers are the ``models.transformer.init_state_cache`` pytree
with the per-request batch axis widened to ``n_slabs + 1`` (axis 1 of
every leaf, exactly where the KV leaves keep their page axis).  Slab 0
is the PARKING slab, the state twin of the parking page: decode rows
whose request finished mid-scan read and write it instead of a live
slab.  Hybrid families (jamba) hold both kinds at once -- attention
sub-layers page through the KV plane while mamba sub-layers ride one
slab -- and pure-recurrent families (rwkv) hold zero-size KV leaves
and page nothing.  Slabs refcount/alloc/free exactly like pages and
hand off bitwise through ``export_state``/``import_state``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import ssm as _ssm
from ..models import transformer as _transformer
from ..models.attention import kv_scale_cols

__all__ = ["PARKING_PAGE", "PARKING_SLAB", "PagedKVPool",
           "paged_kv_bytes_per_step", "page_handoff_bytes",
           "state_slab_bytes"]

_POOL_KEYS = ("k_codes", "v_codes", "k_scale", "v_scale")

# Page 0 is never allocated: padded batch rows -- and, in the multi-step
# decode dispatch, rows whose request finished mid-scan -- re-map their
# writes here (page-table row of zeros, position 0), so dead decode
# iterations are no-op DMAs against one scratch page instead of
# corrupting live pages.  Its scales initialize to the neutral 1.0, so
# even a masked read through it dequantizes to finite values.
PARKING_PAGE = 0

# Slab 0 plays the same role on the state plane: decode rows of
# finished requests gather/scatter their (discarded) state here.
PARKING_SLAB = 0


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_pages(dst: jax.Array, src: jax.Array,
                   idx: jax.Array) -> jax.Array:
    """In-place page scatter: ``dst`` is donated, so XLA updates the pool
    buffer where it lives instead of copying the whole L x P x page
    array per admission."""
    return dst.at[:, idx].set(src)


def _init_state_buffers(cfg: ModelConfig, n_slabs: int,
                        kv_group: Optional[int]):
    """Slab buffers: the quantized-state pytree with the batch axis
    widened to ``n_slabs + 1``.  Built from shape specs only (no
    quantization runs): codes start at 0, scales at the neutral 1.0,
    so a masked read through the parking slab dequantizes to zeros."""
    specs = jax.eval_shape(
        lambda: _ssm.quantize_state(
            _transformer.init_state_cache(cfg, n_slabs + 1), kv_group))

    def init(path, sds):
        if path[-1].key.endswith("_scale"):
            return jnp.ones(sds.shape, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree_util.tree_map_with_path(init, specs)


class PagedKVPool:
    """Fixed-size paged posit8 cache pool with host-side accounting.

    Two page kinds (see the module contract): growable attention-KV
    pages and fixed-size recurrent-state slabs.  ``n_pages`` counts
    allocatable KV pages and ``n_slabs`` allocatable state slabs; one
    extra parking page / slab (id 0) is added on top of each, so the
    device arrays hold ``n_pages + 1`` pages and ``n_slabs + 1`` slabs.
    """

    # layer kinds per family: which cache planes the pool must hold
    _FAMILY_KINDS = {"dense": ("kv",), "moe": ("kv",),
                     "ssm": ("state",), "hybrid": ("kv", "state")}

    @classmethod
    def page_kinds(cls, cfg: ModelConfig) -> tuple:
        """Cache kinds the config's layer mix needs: ``"kv"`` if any
        layer is attention, ``"state"`` if any layer is recurrent.
        Raises (naming the supported families) for anything else --
        the single copy of the capability check, shared with
        ``launch.specs.paged_cache_specs`` so lowering and runtime
        reject the same configs with the same error."""
        kinds = cls._FAMILY_KINDS.get(cfg.family)
        if kinds is None:
            raise ValueError(
                f"no page-kind mapping for family {cfg.family!r}: the "
                f"paged serving plane supports "
                f"{sorted(cls._FAMILY_KINDS)} (attention layers page "
                f"KV; recurrent layers ride fixed-size state slabs)")
        return kinds

    @classmethod
    def validate_family(cls, cfg: ModelConfig) -> None:
        cls.page_kinds(cfg)

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 kv_group: Optional[int] = None, n_slabs: int = 0):
        kinds = self.page_kinds(cfg)
        self.has_kv = "kv" in kinds
        self.has_state = "state" in kinds
        self.cfg = cfg
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.kv_group = kv_group
        hd = cfg.resolved_head_dim
        self.gs = kv_scale_cols(hd, kv_group)
        # KV leaves span the ATTENTION layers only (= all layers for
        # dense/moe, one per group for hybrid, none for pure-recurrent
        # -- the leaves stay present at L=0 so the key set is uniform)
        self.kv_layers = cfg.n_attn_layers if self.has_kv else 0
        P = self.n_pages + 1
        code_shape = (self.kv_layers, P, self.page_size, cfg.n_kv_heads, hd)
        scale_shape = code_shape[:-1] + (self.gs,)
        self.k_codes = jnp.zeros(code_shape, jnp.uint8)
        self.v_codes = jnp.zeros(code_shape, jnp.uint8)
        self.k_scale = jnp.ones(scale_shape, jnp.bfloat16)
        self.v_scale = jnp.ones(scale_shape, jnp.bfloat16)
        # LIFO free list: recently-freed pages are re-used first.  The
        # refcount map mirrors it so alloc/free can assert their
        # invariants in O(1) per page (the old ``pg not in self._free``
        # guard was a linear scan -- O(P^2) to retire a long request);
        # ``_allocated`` == the pages with refcount >= 1 (prefix-shared
        # pages carry one count per holder, see the module contract).
        self._free: List[int] = list(range(P - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        self._allocated: set = set()
        self.alloc_peak = 0
        # state-slab plane: same accounting discipline, own id space
        self.n_slabs = int(n_slabs) if self.has_state else 0
        self.state: Dict[str, Any] = {}
        if self.has_state:
            self.state = _init_state_buffers(cfg, self.n_slabs, kv_group)
        self._slab_free: List[int] = list(range(self.n_slabs, 0, -1))
        self._slab_ref: Dict[int, int] = {}
        self._slab_allocated: set = set()
        self.slab_alloc_peak = 0

    # -- accounting ---------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_pages / max(self.n_pages, 1)

    def pages_for(self, tokens: int) -> int:
        """KV pages needed to hold ``tokens`` cache slots (0 for
        pure-recurrent families: their whole footprint is one slab)."""
        if not self.has_kv:
            return 0
        return -(-tokens // self.page_size)

    @property
    def free_slabs(self) -> int:
        return len(self._slab_free)

    @property
    def used_slabs(self) -> int:
        return self.n_slabs - len(self._slab_free)

    def register_gauges(self, registry, namespace: str = "pool") -> None:
        """Expose the pool's occupancy accounting as callback gauges on
        an ``obs.MetricRegistry``.  Everything reads existing properties
        lazily at snapshot time, so the alloc/free hot path stays
        untouched; ``page_bytes`` is the closed-form per-page byte model
        (``page_handoff_bytes``) the handoff tie-outs check against."""
        registry.gauge(f"{namespace}/n_pages", fn=lambda: self.n_pages)
        registry.gauge(f"{namespace}/used_pages", fn=lambda: self.used_pages)
        registry.gauge(f"{namespace}/free_pages", fn=lambda: self.free_pages)
        registry.gauge(f"{namespace}/utilization",
                       fn=lambda: self.utilization)
        registry.gauge(f"{namespace}/alloc_peak", fn=lambda: self.alloc_peak)
        registry.gauge(
            f"{namespace}/page_bytes",
            fn=lambda: page_handoff_bytes(self.cfg, self.page_size,
                                          self.kv_group))
        if self.has_state:
            registry.gauge(f"{namespace}/n_slabs", fn=lambda: self.n_slabs)
            registry.gauge(f"{namespace}/used_slabs",
                           fn=lambda: self.used_slabs)
            registry.gauge(f"{namespace}/free_slabs",
                           fn=lambda: self.free_slabs)
            registry.gauge(f"{namespace}/slab_alloc_peak",
                           fn=lambda: self.slab_alloc_peak)
            registry.gauge(
                f"{namespace}/slab_bytes",
                fn=lambda: state_slab_bytes(self.cfg, self.kv_group))

    # -- alloc / free -------------------------------------------------------

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages off the free list at refcount 1; None (and no
        change) if the pool cannot satisfy the request."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for pg in got:
            assert pg not in self._allocated, f"page {pg} double-allocated"
            self._allocated.add(pg)
            self._ref[pg] = 1
        self.alloc_peak = max(self.alloc_peak, self.used_pages)
        return got

    def incref(self, pages: List[int]) -> None:
        """Add one holder to already-allocated pages (prefix sharing:
        a request attaching cached prompt-prefix pages, or the prefix
        index registering a freshly prefilled prefix)."""
        for pg in pages:
            assert pg in self._allocated, f"incref of unallocated page {pg}"
            self._ref[pg] += 1

    def free(self, pages: List[int]) -> None:
        """Drop ONE reference per page; a page returns to the free list
        only when its last holder lets go (decref -- the refcount form
        of the old free, which is the refcount == 1 special case)."""
        for pg in pages:
            assert 0 < pg <= self.n_pages, pg
            assert pg in self._allocated, f"double free of page {pg}"
            self._ref[pg] -= 1
            if self._ref[pg] == 0:
                del self._ref[pg]
                self._allocated.remove(pg)
                self._free.append(pg)

    def refcount(self, pg: int) -> int:
        """Current holder count of a page (0 = free)."""
        return self._ref.get(pg, 0)

    # -- slab alloc / free (state plane: same discipline, own id space) -----

    def alloc_slab(self) -> Optional[int]:
        """Pop ONE slab at refcount 1; None (and no change) if the
        state plane is exhausted.  A request needs exactly one slab for
        its whole lifetime -- there is no multi-slab allocation."""
        assert self.has_state, "slab alloc on a pool without state"
        if not self._slab_free:
            return None
        sl = self._slab_free.pop()
        assert sl not in self._slab_allocated, f"slab {sl} double-allocated"
        self._slab_allocated.add(sl)
        self._slab_ref[sl] = 1
        self.slab_alloc_peak = max(self.slab_alloc_peak, self.used_slabs)
        return sl

    def incref_slab(self, sl: int) -> None:
        assert sl in self._slab_allocated, f"incref of unallocated slab {sl}"
        self._slab_ref[sl] += 1

    def free_slab(self, sl: int) -> None:
        """Decref; the slab returns to the free list when the last
        holder lets go (mirrors :meth:`free`)."""
        assert 0 < sl <= self.n_slabs, sl
        assert sl in self._slab_allocated, f"double free of slab {sl}"
        self._slab_ref[sl] -= 1
        if self._slab_ref[sl] == 0:
            del self._slab_ref[sl]
            self._slab_allocated.remove(sl)
            self._slab_free.append(sl)

    def slab_refcount(self, sl: int) -> int:
        return self._slab_ref.get(sl, 0)

    # -- device state -------------------------------------------------------

    def device_state(self) -> Dict[str, Any]:
        """The pool leaves a paged decode step reads AND writes.  KV
        leaves appear only for attention-bearing families and the
        ``"state"`` subtree only for recurrent ones, so each family's
        decode-loop carry is exactly its resident cache -- no zero-size
        ballast rides through jit donation."""
        out: Dict[str, Any] = {}
        if self.has_kv:
            out.update({k: getattr(self, k) for k in _POOL_KEYS})
        if self.has_state:
            out["state"] = self.state
        return out

    def set_device_state(self, state: Dict[str, Any]) -> None:
        if self.has_kv:
            for k in _POOL_KEYS:
                setattr(self, k, state[k])
        if self.has_state:
            self.state = state["state"]

    @staticmethod
    def device_specs(cfg: ModelConfig, n_pages: int, page_size: int,
                     kv_group: Optional[int] = None,
                     n_slabs: int = 0) -> Dict[str, Any]:
        """ShapeDtypeStructs of the pool leaves (dry-run lowering)."""
        kinds = PagedKVPool.page_kinds(cfg)
        out: Dict[str, Any] = {}
        if "kv" in kinds:
            hd = cfg.resolved_head_dim
            gs = kv_scale_cols(hd, kv_group)
            cs = (cfg.n_attn_layers, n_pages + 1, page_size,
                  cfg.n_kv_heads, hd)
            out.update({
                "k_codes": jax.ShapeDtypeStruct(cs, jnp.uint8),
                "v_codes": jax.ShapeDtypeStruct(cs, jnp.uint8),
                "k_scale": jax.ShapeDtypeStruct(cs[:-1] + (gs,),
                                                jnp.bfloat16),
                "v_scale": jax.ShapeDtypeStruct(cs[:-1] + (gs,),
                                                jnp.bfloat16),
            })
        if "state" in kinds:
            out["state"] = jax.eval_shape(
                lambda: _ssm.quantize_state(
                    _transformer.init_state_cache(cfg, n_slabs + 1),
                    kv_group))
        return out

    # -- data movement ------------------------------------------------------

    def write_prefill(self, cache_q, pages: List[int]) -> None:
        """Scatter a quantized prefill cache into allocated pages.

        ``cache_q``: the scan-stacked quantized cache of a B=1 prefill
        whose seq length is a multiple of ``page_size`` -- leaves
        (L, 1, S, Kh, X).  The first S/page_size entries of ``pages``
        receive the S tokens in logical order."""
        self.write_chunk(cache_q, pages, 0)

    def write_chunk(self, cache_q, pages: List[int], start: int) -> None:
        """Scatter one quantized prefill CHUNK into a request's pages --
        the partial form of :func:`write_prefill` (``start=0`` with a
        whole-prefix chunk IS write_prefill).

        ``cache_q``: quantized B=1 chunk, leaves (L, 1, C, Kh, X) with
        C a multiple of ``page_size``.  ``start`` is the chunk's first
        token slot within the request; it must be page-aligned (the
        chunk/page contract above), so the chunk occupies page-table
        slots ``start/page_size ..`` and the scatter stays whole-page.
        A final chunk padded past the request's live prefix may own
        fewer pages than C/page_size: only ``pages[start/page_size:]``
        are written and the trailing pad pages are dropped."""
        leaf = cache_q["k_codes"]
        L, b, c = leaf.shape[:3]
        assert b == 1, "prefill writes are per-request (B=1)"
        if c % self.page_size:
            # recurrent-family prefill chunks are UNPADDED (pad tokens
            # would corrupt the carried state), so a hybrid prefix's
            # final chunk may end mid-page: pad the trailing block here
            # instead.  The pad slots hold zero codes / neutral scales
            # and are either overwritten by decode or never read (the
            # live mask is positional), exactly like monolithic pad.
            pad = self.page_size - c % self.page_size
            cache_q = {
                key: jnp.pad(
                    cache_q[key],
                    [(0, pad) if ax == 2 else (0, 0)
                     for ax in range(cache_q[key].ndim)],
                    constant_values=1.0 if key.endswith("_scale") else 0)
                for key in _POOL_KEYS}
            c += pad
        assert start % self.page_size == 0, (start, self.page_size)
        first = start // self.page_size
        nblk = min(c // self.page_size, len(pages) - first)
        assert nblk > 0, (start, c, len(pages))
        idx = jnp.asarray(pages[first:first + nblk], jnp.int32)
        s = nblk * self.page_size
        for key in _POOL_KEYS:
            src = cache_q[key][:, 0, :s]                 # (L, S, Kh, X)
            src = src.reshape(L, nblk, self.page_size, *src.shape[2:])
            setattr(self, key, _scatter_pages(getattr(self, key), src, idx))


    # -- page handoff (disaggregated prefill/decode) ------------------------

    def export_pages(self, pages: List[int]) -> Dict[str, jax.Array]:
        """Gather whole pages as a detachable payload -- the prefill
        side of the disaggregated page handoff (``serve/disagg.py``).

        Returns ``{key: (L, n, page, Kh, X)}`` device arrays holding the
        posit8 codes + po2 group scales of ``pages`` in logical order --
        exactly the bytes the handoff moves, ~4x smaller than a bf16
        cache.  The gather is a pure functional read: the returned
        arrays do not alias the pool leaves, so the caller may ``free``
        (and the pool re-use) the source pages immediately, even while
        the gather is still dispatching asynchronously."""
        idx = jnp.asarray(pages, jnp.int32)
        return {key: getattr(self, key)[:, idx] for key in _POOL_KEYS}

    def import_pages(self, payload: Dict[str, jax.Array],
                     pages: List[int]) -> None:
        """Scatter an exported payload into this pool's ``pages`` -- the
        decode side of the handoff.  The destination pool must share the
        source's geometry (page size, layer count, head layout); the
        page IDS need not match -- the request's new page-table row is
        simply the destination list.  Codes and scales land bitwise, so
        decode over imported pages reproduces the source pool's reads
        exactly."""
        leaf = payload["k_codes"]
        assert leaf.shape[0] == self.kv_layers, leaf.shape
        assert leaf.shape[2] == self.page_size, \
            (leaf.shape, self.page_size)
        assert leaf.shape[1] == len(pages), (leaf.shape, len(pages))
        idx = jnp.asarray(pages, jnp.int32)
        for key in _POOL_KEYS:
            setattr(self, key,
                    _scatter_pages(getattr(self, key), payload[key], idx))

    def gather_request(self, pages: List[int]) -> Dict[str, jax.Array]:
        """Read a request's pages back as a contiguous (1, T, Kh, X)
        quantized cache per layer (debug / test oracle)."""
        idx = jnp.asarray(pages, jnp.int32)
        out = {}
        for key in _POOL_KEYS:
            x = getattr(self, key)[:, idx]               # (L, n, page, ...)
            out[key] = x.reshape(x.shape[0], 1, -1, *x.shape[3:])
        return out

    # -- state slab movement ------------------------------------------------

    def write_state(self, state_q, slab: int) -> None:
        """Scatter one request's quantized state into its slab -- the
        state twin of :meth:`write_prefill` (prefill completion writes
        the final carried state here ONCE; decode then rewrites the
        slab in place inside the jitted loop).  ``state_q`` leaves have
        batch width 1 on axis 1."""
        idx = jnp.asarray([slab], jnp.int32)
        self.state = jax.tree.map(
            lambda dst, src: _scatter_pages(dst, src, idx),
            self.state, state_q)

    def export_state(self, slab: int) -> Dict[str, Any]:
        """Gather one slab as a detachable payload (batch width 1) --
        the state side of the disagg handoff AND the scheduler's
        preemption snapshot.  A pure functional read, like
        :meth:`export_pages`: valid after the slab is freed."""
        idx = jnp.asarray([slab], jnp.int32)
        return jax.tree.map(lambda leaf: leaf[:, idx], self.state)

    def import_state(self, payload, slab: int) -> None:
        """Scatter an exported state payload into this pool's ``slab``
        (decode side of the handoff / preemption resume).  Codes and
        scales land bitwise, so the restored request's decode continues
        exactly where the source left off."""
        self.write_state(payload, slab)

    # -- roofline -----------------------------------------------------------

    def modeled_bytes_per_step(self, positions) -> float:
        """Modeled cache HBM bytes one batched decode step moves, per
        page kind: each live request reads its ceil((pos+1)/page) live
        KV pages across the attention layers, and reads + rewrites its
        whole state slab -- a function of LIVE pages/slabs, never of
        any ``max_len``."""
        total = paged_kv_bytes_per_step(self.cfg, positions,
                                        self.page_size, self.kv_group)
        if self.has_state:
            n_live = int(np.atleast_1d(np.asarray(positions)).size)
            total += 2.0 * state_slab_bytes(self.cfg, self.kv_group) * n_live
        return total


def paged_kv_bytes_per_step(cfg: ModelConfig, positions, page_size: int,
                            kv_group: Optional[int] = None) -> float:
    """Companion of ``roofline.analysis.decode_kv_bytes`` for the paged
    plane: codes+scales bytes of the live pages of every request."""
    hd = cfg.resolved_head_dim
    gs = kv_scale_cols(hd, kv_group)
    toks = sum(-(-(int(p) + 1) // page_size) * page_size
               for p in np.atleast_1d(np.asarray(positions)))
    return float(2 * cfg.n_attn_layers * cfg.n_kv_heads * toks
                 * (hd * 1 + gs * 2))


def page_handoff_bytes(cfg: ModelConfig, page_size: int,
                       kv_group: Optional[int] = None) -> int:
    """Bytes ONE page moves across the prefill->decode handoff: K+V
    posit8 codes (1 byte/slot/feature) plus bf16 po2 group scales over
    every attention layer -- the exact ``.nbytes`` sum of one page's
    slice of an ``export_pages`` payload, which is what makes the
    handoff ~4x cheaper than shipping bf16 KV."""
    hd = cfg.resolved_head_dim
    gs = kv_scale_cols(hd, kv_group)
    return int(2 * cfg.n_attn_layers * page_size * cfg.n_kv_heads
               * (hd * 1 + gs * 2))


def state_slab_bytes(cfg: ModelConfig, kv_group: Optional[int] = None) -> int:
    """Bytes ONE request's quantized recurrent state occupies -- the
    exact ``.nbytes`` sum of an ``export_state`` payload (posit8 codes
    + bf16 group scales over every recurrent leaf), i.e. the per-kind
    closed form for the "state" plane: a slab costs this much resident,
    a handoff moves this much, and a decode step streams 2x (read +
    rewrite).  0 for pure-attention families."""
    if "state" not in PagedKVPool.page_kinds(cfg):
        return 0
    specs = jax.eval_shape(
        lambda: _ssm.quantize_state(
            _transformer.init_state_cache(cfg, 1), kv_group))
    return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(specs)))
