"""Serving plane: static batching, paged KV, continuous batching.

Two engines share the model step functions:

  * ``ServeEngine`` -- static batching against a dense ``max_len`` cache
    (optionally posit8-quantized); the oracle the paged plane is tested
    against.  Accepts ragged LEFT-padded prompts via
    ``generate(..., lengths=)``.
  * ``ContinuousEngine`` -- continuous batching over a ``PagedKVPool``.

Page-table layout
-----------------
The pool holds posit8 codes + po2 group scales in fixed-size pages,
stacked over layers: ``(L, P, page, Kh, Dh)`` codes and
``(L, P, page, Kh, Gs)`` scales, where ``page`` equals the decode
kernel's KV block (one block partition for paged and contiguous decode)
and a page id indexes all L layers at once.  Page 0 is the parking
page: never allocated; padded batch rows write there and page-table
rows are padded with it.  Each request owns a page-table row
``(NP,) int32`` mapping logical KV block ``t`` to its pool page; decode
gathers blocks through it (Pallas: via the scalar-prefetch index map;
XLA: via a ``fori_loop`` gather) and reads only the live prefix
ceil((pos+1)/page), so per-step KV bytes track LIVE pages, not
``max_len``.

Scheduler contract
------------------
``Scheduler`` (serve/scheduler.py) owns request state + page accounting:
FIFO admission gated on ``pages_for(prefix + 1)`` free pages (the head
blocks the queue -- deterministic, starvation-free), one page allocated
lazily whenever a running request's position crosses a page boundary,
LIFO preemption on pool exhaustion (the youngest running request's
pages are freed and it requeues at the FRONT; its generated tokens are
kept, so resume re-prefills prompt+generated and greedy decoding
continues exactly where it stopped), retire-on-finish (EOS or token
budget) returns pages the same step.  The engine turns that policy into
batched steps: per-request prefill for admissions, one fixed-shape
batched decode for everyone running, per-row sampling and retirement.
"""

from .engine import (ServeEngine, ContinuousEngine,  # noqa: F401
                     build_prefill_step, build_serve_step)
from .paged_kv import PagedKVPool, paged_kv_bytes_per_step  # noqa: F401
from .scheduler import Request, Scheduler  # noqa: F401
