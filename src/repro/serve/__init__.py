"""Serving plane: static batching, paged KV, continuous batching.

Two engines share the model step functions:

  * ``ServeEngine`` -- static batching against a dense ``max_len`` cache
    (optionally posit8-quantized); the oracle the paged plane is tested
    against.  Accepts ragged LEFT-padded prompts via
    ``generate(..., lengths=)``.
  * ``ContinuousEngine`` -- continuous batching over a ``PagedKVPool``.
  * ``DisaggEngine`` -- disaggregated prefill/decode serving: a
    ``PrefillWorker`` (the chunk-budget admitter) and a ``DecodeWorker``
    (the K-step device-resident loop) over two pools, connected by a
    double-buffered ``PageHandoffChannel`` that moves only posit8 page
    codes + group scales (see below).

Page-table layout
-----------------
The pool holds posit8 codes + po2 group scales in fixed-size pages,
stacked over layers: ``(L, P, page, Kh, Dh)`` codes and
``(L, P, page, Kh, Gs)`` scales, where ``page`` equals the decode
kernel's KV block (one block partition for paged and contiguous decode)
and a page id indexes all L layers at once.  Page 0 is the parking
page: never allocated; padded batch rows write there and page-table
rows are padded with it.  Each request owns a page-table row
``(NP,) int32`` mapping logical KV block ``t`` to its pool page; decode
gathers blocks through it (Pallas: via the scalar-prefetch index map;
XLA: via a ``fori_loop`` gather) and reads only the live prefix
ceil((pos+1)/page), so per-step KV bytes track LIVE pages, not
``max_len``.

Paged STATE (recurrent families)
--------------------------------
Attention layers are the only layers whose cache GROWS; SSM/RWKV
layers carry a fixed-size recurrent state.  The pool therefore serves
two page KINDS (``PagedKVPool.page_kinds``): growable attention-KV
pages as above, and fixed-size per-request STATE SLABS -- the model's
recurrent-state pytree, posit8 codes + po2 group scales per leaf,
stacked ``n_slabs + 1`` wide on the batch axis with slab 0 as the
parking slab.  ``ssm`` requests hold one slab and zero pages; hybrids
hold one slab plus pages for their attention layers; dense/moe pools
carry no slab plane at all.  A request's state footprint is CONSTANT:
admission gates on one free slab, and ``ensure_capacity`` never grows
it -- decode rewrites the slab in place (gather by ``slab_table``,
dequantize, step, requantize, scatter) inside the same fused loop.
Preemption of a RUNNING stateful request snapshots its slab
(``export_state``) instead of discarding work: resume imports it
bitwise and continues exactly, no re-prefill.  See ``docs/serving.md``
("Paged state") for the kind taxonomy and the parity ladder.

Scheduler contract
------------------
``Scheduler`` (serve/scheduler.py) owns request state + page accounting:
FIFO admission gated on ``pages_for(prefix + 1)`` UNCLAIMED free pages
(the head blocks the queue -- deterministic, starvation-free; pages of
mid-prefill requests' outstanding claims are excluded so co-admitted
prefills never race each other), pages allocated lazily -- per prefill
CHUNK while PREFILLING, then one page whenever a running request's
position crosses a page boundary -- LIFO preemption on pool exhaustion
(the youngest request's pages are freed and it requeues at the FRONT;
a RUNNING victim keeps its generated tokens, so resume re-prefills
prompt+generated and greedy decoding continues exactly where it
stopped; a PREFILLING victim restarts its prefill from chunk 0),
retire-on-finish (EOS or token budget) returns pages the same step.

The engine turns that policy into batched steps with a load-bearing
ORDER: capacity for the running batch first (pre-claiming the whole
``decode_steps`` window), then admission, then chunked prefill inside
a per-step token budget (``prefill_chunk_tokens``), then ONE
device-resident decode dispatch for everyone running -- ``decode_steps``
fused decode+sample iterations under a single ``lax.scan`` (greedy
argmax or seeded per-(request, token-index) categorical; positions
bump on device; rows hitting EOS / budget mid-scan freeze and re-map
their writes to the parking page) -- then retirement from the one
``(B, K)`` token sync.  The ``(B, NP)`` page table is epoch-cached on
device: it re-uploads only when the scheduler's mapping epoch or the
batch row order changes.  Admitting before
capacity (the PR 3 order) let a newcomer take the last free page only
to be preempted as the youngest victim in the same step -- its whole
prefill wasted, every step, while pool pressure lasted.  The token
budget bounds p99 decode-step latency by the chunk, not the longest
prompt: a long-prompt arrival costs a chain of chunk steps interleaved
with decode instead of one monolithic stall.

Prefix caching (copy-on-write)
------------------------------
``ContinuousEngine(prefix_cache=True)``: whole prompt-prefix pages of
completed prefills are published in the scheduler's ``PrefixIndex``
(a digest chain over whole-page token blocks, verified against the
exact stored block so collisions degrade to misses) and SHARED
read-only with later requests whose prompt opens with the same blocks.
The pool counts holders per page (``free`` is a decref), admission
budgets -- and prefill computes -- only the NEW pages a hit still
needs, and when the free list runs dry, unreferenced cached pages are
evicted LRU (leaf-first) before anyone is preempted.  Hits force
``prefill_context="pages"`` so the remaining chunks attend to the
prefix through the same posit8 page reads a cold run performs; the
shared pages hold bitwise the codes that cold run would write, so
temperature-0 outputs match a cache-off engine token for token.  See
``serve/paged_kv.py`` for the share/refcount/copy-on-write contract.

Disaggregated prefill/decode (page handoff)
-------------------------------------------
``DisaggEngine`` (serve/disagg.py) splits the interleaved engine along
its roofline boundary: a compute-bound ``PrefillWorker`` keeps the
whole admitter (chunk budget, prefix cache, preemption) over its own
pool, a memory-bound ``DecodeWorker`` runs the K-step device-resident
loop uninterrupted over another, and completed prefills cross between
them as EXPORTED page payloads -- posit8 codes + po2 group scales, the
wire format IS the pool format, ~4x smaller than a bf16 handoff
(``paged_kv.page_handoff_bytes`` is the exact per-page model).  The
decode dispatch launches async BEFORE the prefill step runs, so
prefill chunks hide behind the decode scan; backpressure is
structural (a parked completion holds its pages + batch slot, the
channel is depth-bounded, a handoff waits for decode pages) and decode
pool exhaustion BOUNCES the youngest request back to the admitter --
the disaggregated analogue of LIFO preemption.  Temperature-0 outputs
are token-for-token the interleaved engine's (and, on the carry
context, the static oracle's): both sides run the same chunk /
dispatch code and the handoff is bitwise.
"""

from .disagg import (DisaggEngine, DecodeWorker,  # noqa: F401
                     PageHandoffChannel, PrefillWorker)
from .engine import (ServeEngine, ContinuousEngine,  # noqa: F401
                     build_prefill_step, build_prefill_chunk_step,
                     build_serve_step)
from .paged_kv import (PagedKVPool, page_handoff_bytes,  # noqa: F401
                       paged_kv_bytes_per_step, state_slab_bytes)
from .scheduler import (DecodeRunner, PrefixIndex,  # noqa: F401
                        Request, Scheduler)
