from .engine import ServeEngine, build_prefill_step, build_serve_step  # noqa: F401
