"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns exactly what ``train_step`` /
``prefill_step`` / ``serve_step`` take, as abstract values, so
``jax.jit(...).lower(**specs)`` never touches device memory.  Audio/vision
frontends are stubs per the assignment: the specs carry precomputed
frame/patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as T

__all__ = ["batch_specs", "cache_specs", "paged_cache_specs",
           "chunk_prefill_specs", "handoff_specs", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, b: int, s: int,
                with_labels: bool = True) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["frame_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
        if cfg.frontend == "vision":
            out["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, b: int, max_len: int,
                quantized_kv: bool = False, kv_group=None):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, b, max_len, quantized_kv, kv_group))


def paged_cache_specs(cfg: ModelConfig, b: int, max_len: int,
                      pool_frac: float = 0.25, kv_group=None,
                      page_size=None) -> Dict[str, Any]:
    """Abstract paged decode cache: pool leaves + routing tables.

    The page kinds come from the config's layer mix
    (``PagedKVPool.page_kinds`` -- the capability check; unknown
    families are rejected with the supported list).  Attention-bearing
    families get the KV pool pages plus ``page_table (B, NP)``; the
    pool holds ``pool_frac`` of the worst-case ``b * max_len`` token
    capacity (continuous batching's bet: live tokens << max_len) while
    the page table still spans the full ``max_len`` per request.
    Recurrent families get the quantized state-slab plane (``b`` slabs
    -- the footprint is per-request constant, one slab each) plus
    ``slab_table (B,)``; hybrids carry both.  Pool leaves ride exactly
    as the engine builds them; the tables and ``positions (B,)`` sit
    once at the top level (uploaded once, broadcast inside the layer
    scan -- never tiled L x), so the KV-kind specs lower through
    ``build_serve_step`` unchanged and the state-kind specs mirror the
    ``ContinuousEngine`` decode-loop carry."""
    from ..kernels.flash_decode import default_kv_block
    from ..serve.paged_kv import PagedKVPool
    kinds = PagedKVPool.page_kinds(cfg)
    psize = page_size or default_kv_block(max_len)
    if max_len % psize:
        raise ValueError(
            f"page_size {psize} must divide max_len {max_len}; the "
            f"page table would truncate the last {max_len % psize} "
            f"tokens")
    npp = max_len // psize
    n_pages = max(int(pool_frac * b * npp), npp)
    specs = PagedKVPool.device_specs(
        cfg, n_pages, psize, kv_group,
        n_slabs=b if "state" in kinds else 0)
    if "kv" in kinds:
        specs["page_table"] = _sds((b, npp), jnp.int32)
    if "state" in kinds:
        specs["slab_table"] = _sds((b,), jnp.int32)
    specs["positions"] = _sds((b,), jnp.int32)
    return specs


def chunk_prefill_specs(cfg: ModelConfig, chunk: int,
                        ctx_len: int) -> Dict[str, Any]:
    """Abstract inputs of ``serve.engine.build_prefill_chunk_step``
    (carry form): ONE chunk of ``chunk`` tokens attending to a
    ``ctx_len``-token bf16 KV carry of the already-prefilled prefix.
    With ``ctx_len = S - chunk`` this is the latency-critical LAST
    chunk of an S-token prompt -- the largest step chunked prefill
    ever pays, which is exactly what the ``--chunked-prefill`` dry-run
    cell must prove fits and costs."""
    hd = cfg.resolved_head_dim
    kv = (cfg.n_layers, 1, ctx_len, cfg.n_kv_heads, hd)
    return {
        "tokens": _sds((1, chunk), jnp.int32),
        "ctx": {"k": _sds(kv, jnp.bfloat16), "v": _sds(kv, jnp.bfloat16)},
        "start": _sds((1,), jnp.int32),
    }


def handoff_specs(cfg: ModelConfig, n_pages: int,
                  page_size: int, kv_group=None) -> Dict[str, Any]:
    """Abstract page-handoff payload of disaggregated serving
    (``serve.disagg.PageHandoffChannel``): the ``n_pages`` exported
    pages of ONE completed prefill, in pool wire format -- posit8 codes
    ``(La, n, page, Kh, Dh)`` uint8 + po2 group scales
    ``(La, n, page, Kh, Gs)`` bf16 (``PagedKVPool.export_pages``),
    where ``La`` counts only the ATTENTION layers (hybrids page KV for
    those alone; recurrent layers ride the state slab, not pages).  The
    summed ``.nbytes`` of these specs is exactly
    ``n_pages * paged_kv.page_handoff_bytes(cfg, page_size, kv_group)``
    -- what the disagg bench asserts its measured channel traffic
    against.  Stateful families add ``state_slab_bytes`` per handoff on
    top (the nested payload's ``"state"`` part, not modeled here)."""
    from ..models.attention import kv_scale_cols
    from ..serve.paged_kv import PagedKVPool
    PagedKVPool.validate_family(cfg)
    hd = cfg.resolved_head_dim
    gs = kv_scale_cols(hd, kv_group)
    code = (cfg.n_attn_layers, n_pages, page_size, cfg.n_kv_heads, hd)
    scale = code[:-1] + (gs,)
    return {"k_codes": _sds(code, jnp.uint8),
            "v_codes": _sds(code, jnp.uint8),
            "k_scale": _sds(scale, jnp.bfloat16),
            "v_scale": _sds(scale, jnp.bfloat16)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                quantized_kv: bool = False) -> Dict[str, Any]:
    """Abstract inputs for the step function that ``shape.kind`` lowers.
    (Paged decode cells swap ``cache`` for :func:`paged_cache_specs` --
    the dry-run driver composes that itself.)"""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, b, s)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, b, s, with_labels=False)}
    # decode: one new token against a seq_len cache
    specs: Dict[str, Any] = {
        "tokens": _sds((b, 1), jnp.int32),
        "cache": cache_specs(cfg, b, s, quantized_kv),
        "pos": _sds((), jnp.int32),
    }
    return specs
