"""Serving CLI: batched generation with the packed-weight plane.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --policy mixed --batch 4 --prompt-len 16 --steps 32 [--quantized-kv]

``--continuous`` serves the same request mix through the paged-KV
``ContinuousEngine`` instead: per-request prompt/generation lengths,
FIFO admission against a page pool, one batched decode step for all
live requests (see serve/__init__ for the page-table layout).
``--prefill-chunk N`` turns on chunked paged prefill: one step pays at
most N prefill tokens, so a long prompt no longer stalls the running
decode batch for a full prefill.  ``--prefix-cache`` shares the request
mix's common preamble through the pool's copy-on-write prefix cache:
every request after the first sharer skips re-prefilling the matched
whole pages.

``--decode-steps K`` makes the decode loop device-resident: one jitted
dispatch runs K fused decode+sample iterations (positions bump on
device, EOS/budget rows park mid-scan) and the host syncs one (B, K)
token buffer -- K host round trips become one, and the (B, vocab)
logits never leave the device.

  ... --continuous --batch 8 --n-pages 48 [--page-size 16]
      [--prefill-chunk 16] [--prefix-cache] [--decode-steps 4]

``--disagg`` serves the same mix through the disaggregated
``DisaggEngine`` instead: a prefill worker (admission + chunk budget)
and an uninterrupted decode worker over separate page pools, joined by
a double-buffered posit8 page-handoff channel; decode dispatches
overlap the prefill chunks.  With more than one device the workers'
programs are placed on distinct device slices
(``parallel.sharding.split_devices``).

  ... --disagg --batch 8 --n-pages 48 --prefill-chunk 16 --decode-steps 4

``--trace out.json`` records request-lifecycle events and per-step
spans on the paged engines and writes a Chrome-trace JSON (open in
Perfetto / chrome://tracing); ``--metrics`` prints a Prometheus-style
snapshot of the engine's metric registry.  See docs/observability.md.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.policy import PrecisionPolicy
from ..models import zoo
from ..serve.engine import ContinuousEngine, ServeEngine


def _static(args, cfg, params, policy) -> None:
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.steps + 8,
                      quantized_kv=args.quantized_kv, policy=policy)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    out = eng.generate(toks, steps=args.steps,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    tps = args.batch * args.steps / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(out[:, args.prompt_len:][:2])


def _continuous(args, cfg, params, policy) -> None:
    from ..obs import TraceRecorder
    rec = TraceRecorder() if (args.trace or args.metrics) else None
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.steps + 8
    page_size = args.page_size
    if args.prefill_chunk and page_size is None:
        page_size = args.prefill_chunk       # chunk == k * page, k = 1
    if args.prefix_cache:
        # the shared preamble rides ON TOP of the nominal prompt
        # length: size the page table for it too, or the longest
        # requests would have no token budget left
        if page_size is None:
            from ..kernels.flash_decode import default_kv_block
            page_size = default_kv_block(max_len)
        max_len += page_size
    if args.prefill_chunk and max_len % args.prefill_chunk:
        # chunk | max_len is the page-table contract; round up
        max_len += args.prefill_chunk - max_len % args.prefill_chunk
    if page_size is not None and max_len % page_size:
        # the page table maps whole pages; round up exactly like the
        # chunk branch (an explicit --page-size used to crash the
        # engine's divisibility check here)
        max_len += page_size - max_len % page_size
    if args.disagg:
        from ..parallel.sharding import split_devices
        from ..serve.disagg import DisaggEngine
        pdev, ddev = split_devices()
        one = pdev is ddev or pdev[0] == ddev[0]
        eng = DisaggEngine(
            cfg, params, prefill_pages=args.n_pages,
            decode_pages=args.n_pages, page_size=page_size,
            max_batch=args.batch, max_len=max_len, policy=policy,
            temperature=args.temperature,
            prefill_chunk_tokens=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            decode_steps=args.decode_steps,
            prefill_device=None if one else pdev[0],
            decode_device=None if one else ddev[0],
            trace=rec)
    else:
        eng = ContinuousEngine(
            cfg, params, n_pages=args.n_pages, page_size=page_size,
            max_batch=args.batch, max_len=max_len, policy=policy,
            temperature=args.temperature,
            prefill_chunk_tokens=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            decode_steps=args.decode_steps,
            trace=rec)
    # ragged request mix around the CLI's nominal prompt/step counts;
    # under --prefix-cache every prompt opens with one shared page-sized
    # preamble (the XR scene/system prompt ahead of every query), so
    # request 2.. re-prefills only its unique tail
    preamble = rng.integers(0, cfg.vocab, (eng.page_size,)) \
        if args.prefix_cache else None
    n_req = 2 * args.batch
    rids = []
    for i in range(n_req):
        plen = max(1, args.prompt_len - int(rng.integers(0, 4)))
        steps = max(1, args.steps - int(rng.integers(0, args.steps // 2 + 1)))
        prompt = rng.integers(0, cfg.vocab, (plen,))
        if preamble is not None:
            prompt = np.concatenate([preamble, prompt])
            steps = max(1, min(steps, max_len - prompt.size))
        rids.append(eng.submit(prompt, steps))
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    finished = eng.finished if args.disagg else eng.scheduler.finished
    sched = eng.prefill.scheduler if args.disagg else eng.scheduler
    toks = sum(len(finished[r].generated) for r in rids)
    print(f"served {n_req} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s) over {eng.steps_run} engine steps")
    print(f"decode loop: K={eng.decode_steps}, {eng.decode_dispatches} "
          f"dispatches, {eng.page_table_uploads} page-table uploads, "
          f"{eng.token_host_bytes} token bytes to host "
          f"(logits bytes: {eng.logits_host_bytes})")
    if args.disagg:
        print(f"disagg: {eng.handoffs} handoffs / {eng.handoff_pages} "
              f"pages / {eng.handoff_bytes} posit8 bytes over the "
              f"channel (depth {eng.channel.depth}), "
              f"{eng.decode_bounces} decode-side bounces; pools "
              f"prefill {eng.prefill.pool.n_pages} (peak "
              f"{eng.prefill.pool.alloc_peak}) / decode "
              f"{eng.decode.pool.n_pages} (peak "
              f"{eng.decode.pool.alloc_peak}) x {eng.page_size} slots")
    else:
        print(f"pool: {eng.pool.n_pages} pages x {eng.pool.page_size} "
              f"slots, peak used {eng.pool.alloc_peak}, "
              f"preemptions {sched.preemption_count} "
              f"(mid-prefill {sched.prefill_preemptions}, "
              f"wasted prefill tokens {sched.wasted_prefill_tokens})")
    print(f"prefill: "
          f"{'chunked, %d tokens/step' % eng.prefill_chunk_tokens if eng.prefill_chunk_tokens else 'monolithic'}, "
          f"{eng.prefill_tokens_computed} tokens computed")
    if args.prefix_cache:
        px = sched.prefix
        print(f"prefix cache: {px.hits} hits, {px.hit_tokens} prefill "
              f"tokens served from shared pages, {len(px)} pages cached, "
              f"{px.evictions} evictions")
    for r in rids[:2]:
        print(f"  req {r}: {np.asarray(finished[r].generated)}")
    if rec is not None:
        print("slo (ms):")
        for name, s in rec.slo_summary().items():
            print(f"  {name:>17}: p50 {s['p50']:8.2f}  p95 {s['p95']:8.2f}  "
                  f"p99 {s['p99']:8.2f}  (n={s['n']})")
    if args.trace:
        rec.write_chrome_trace(args.trace)
        print(f"wrote Chrome trace ({len(rec)} events) to {args.trace} -- "
              f"open in Perfetto (ui.perfetto.dev) or chrome://tracing")
    if args.metrics:
        print(eng.metrics.prometheus_text(), end="")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="mixed")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--quantized-kv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", "--paged", action="store_true",
                    help="serve through the paged-KV ContinuousEngine")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode serving: split "
                         "the paged engine into a prefill worker and an "
                         "uninterrupted decode worker joined by a "
                         "posit8 page-handoff channel (implies paged "
                         "serving; each side gets its own --n-pages "
                         "pool)")
    ap.add_argument("--n-pages", type=int, default=48,
                    help="paged pool size (allocatable pages)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per page (default: the decode KV block, "
                         "or --prefill-chunk when that is set)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked paged prefill: max prefill tokens one "
                         "engine step may process (default: monolithic)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share whole common-preamble pages between "
                         "requests (copy-on-write prefix caching); the "
                         "demo mix gets a one-page shared preamble")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode iterations per jitted dispatch: one "
                         "host round trip drives K on-device "
                         "decode+sample steps (temperature-0 output is "
                         "identical for every K)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record request-lifecycle events + step spans "
                         "and write a Chrome-trace JSON (open in "
                         "Perfetto); paged engines only")
    ap.add_argument("--metrics", action="store_true",
                    help="print a Prometheus-style text snapshot of the "
                         "engine's metric registry after the run; paged "
                         "engines only")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    policy = None
    if args.policy not in ("fp32", "none"):
        policy = (PrecisionPolicy.paper_mixed() if args.policy == "mixed"
                  else PrecisionPolicy.uniform(args.policy))
    if args.continuous or args.disagg:
        _continuous(args, cfg, params, policy)
    else:
        if args.trace or args.metrics:
            print("note: --trace/--metrics need the paged engines "
                  "(--continuous/--disagg); the static engine carries "
                  "no telemetry")
        _static(args, cfg, params, policy)


if __name__ == "__main__":
    main()
