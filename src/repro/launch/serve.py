"""Serving CLI: batched generation with the packed-weight plane.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --policy mixed --batch 4 --prompt-len 16 --steps 32 [--quantized-kv]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.policy import PrecisionPolicy
from ..models import zoo
from ..serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="mixed")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--quantized-kv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = zoo.init_model(jax.random.PRNGKey(0), cfg)
    policy = None
    if args.policy not in ("fp32", "none"):
        policy = (PrecisionPolicy.paper_mixed() if args.policy == "mixed"
                  else PrecisionPolicy.uniform(args.policy))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.steps + 8,
                      quantized_kv=args.quantized_kv, policy=policy)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = eng.generate(toks, steps=args.steps,
                       temperature=args.temperature)
    dt = time.time() - t0
    tps = args.batch * args.steps / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(out[:, args.prompt_len:][:2])


if __name__ == "__main__":
    main()
