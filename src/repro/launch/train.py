"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 100 --batch 8 --seq 128 --policy mixed --qat \
      [--reduced] [--grad-compression posit8] [--opt-dtype posit8]

Single-host driver; the production meshes are exercised by
``repro.launch.dryrun`` (this container has one real device)."""

from __future__ import annotations

import argparse

import jax

from ..configs import get_config
from ..configs.base import RunConfig
from ..data import TokenStream
from ..train.loop import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--policy", default="fp32")
    ap.add_argument("--qat", action="store_true")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(
        arch=args.arch, steps=args.steps, lr=args.lr,
        microbatch=args.microbatch, qat=args.qat,
        precision_policy=args.policy, grad_compression=args.grad_compression,
        opt_state_dtype=args.opt_dtype, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    data = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, frontend=cfg.frontend,
                       d_model=cfg.d_model, n_patches=cfg.n_patches)
    state, hist = train_loop(cfg, run, data)
    print(f"final loss: {hist['loss'][-1]:.4f} at step {int(state.step)}")


if __name__ == "__main__":
    main()
