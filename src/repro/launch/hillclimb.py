import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> measure -> record.

Runs the three selected cells through their iteration ladders and writes
tagged artifacts (artifacts/dryrun/*__<tag>.json) plus a markdown log to
artifacts/perf_log.md.  Iterations it1/it2 are code fixes measured by
re-lowering (the code change is in the tree; the baseline artifacts were
compiled before it).

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C]
"""

import argparse
import json

from .dryrun import lower_cell, save_record

LOG = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "perf_log.md")


def run_variant(arch, shape, tag, hypothesis, **kw):
    rec = lower_cell(arch, shape, verbose=False, **kw)
    path = save_record(rec, tag)
    rf = rec["roofline"]
    row = {
        "arch": arch, "shape": shape, "tag": tag, "hypothesis": hypothesis,
        "t_compute": rf["t_compute_s"], "t_memory": rf["t_memory_s"],
        "t_coll": rf["t_collective_s"], "dominant": rf["dominant"],
        "frac": rf["roofline_fraction"],
        "useful": rf["useful_flops_ratio"],
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
    }
    print(f"[{arch} x {shape}] {tag}: dom={row['dominant']} "
          f"tm={row['t_memory']:.4f} tc={row['t_compute']:.4f} "
          f"tk={row['t_coll']:.4f} frac={row['frac']:.4f} "
          f"temp={row['temp_gib']:.1f}GiB  -- {hypothesis}")
    with open(LOG, "a") as f:
        f.write(json.dumps(row) + "\n")
    return row


def cell_A():
    """command-r-plus-104b x decode_32k -- packed serving, memory-bound:
    the cell most representative of the paper's technique."""
    a, s = "command-r-plus-104b", "decode_32k"
    run_variant(a, s, "hc0", "re-measure baseline after KV-reshard fix "
                "(it1) + lm_head rule fix (it2): expect big t_memory drop "
                "(all-gather of f32 KV per layer eliminated)")
    run_variant(a, s, "hc_kvq", "it3 (re-measured after the fused KV "
                "plane): posit8 KV codes are now consumed directly by the "
                "length-aware decode -- no full-cache bf16 dequant in HBM "
                "per step; KV dominates decode traffic -> t_memory ~ "
                "-30-50% vs bf16 KV", quantized_kv=True)
    run_variant(a, s, "hc_bf16", "control: bf16 dense weights (pre-paper "
                "serving baseline) -- shows the paper's packed-weight gain",
                policy_name="bf16")
    run_variant(a, s, "hc_fp4", "beyond-paper: uniform fp4 weights + "
                "posit8 KV (both planes packed) -- max packing; measures "
                "accuracy-free upper bound", policy_name="fp4",
                quantized_kv=True)


def cell_B():
    """qwen2-0.5b x prefill_32k -- worst baseline roofline fraction
    (0.002): a tiny TP-unfriendly model on 256 chips."""
    a, s = "qwen2-0.5b", "prefill_32k"
    run_variant(a, s, "hc0", "re-measure after it2 lm_head fix")
    run_variant(a, s, "hc_lastlogit", "it3: return only last-position "
                "logits; XLA DCEs (S-1)/S of the lm_head matmul and the "
                "(B,S,V) buffer -> t_compute & t_memory drop "
                "(head is ~40% of this tiny model's FLOPs at 32k)",
                last_logit_only=True)
    run_variant(a, s, "hc_chunk", "bigger attention chunks (4096): fewer, "
                "larger dots -> less per-chunk overhead in bytes-accessed",
                last_logit_only=True, seq_chunk=4096)


def cell_C():
    """kimi-k2-1t-a32b x train_4k -- the paper's technique at 1T-param
    scale (packed/QAT MoE), worst absolute memory pressure."""
    a, s = "kimi-k2-1t-a32b", "train_4k"
    run_variant(a, s, "hc0", "re-measure baseline (mb=4)", microbatch=4)
    run_variant(a, s, "hc_mb8", "microbatch 8: halves per-microbatch "
                "activation transients; HLO flops unchanged",
                microbatch=8)
    run_variant(a, s, "hc_noqat", "ablate QAT fake-quant: isolates its "
                "bytes-accessed contribution (encode+decode of every "
                "expert weight per microbatch)", microbatch=4, qat=False)
    run_variant(a, s, "hc_comp", "posit8 gradient compression w/ error "
                "feedback: DP all-reduce wire bytes / 4",
                microbatch=4, grad_compression="posit8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    args = ap.parse_args()
    if args.cell in ("A", "all"):
        cell_A()
    if args.cell in ("B", "all"):
        cell_B()
    if args.cell in ("C", "all"):
        cell_C()


if __name__ == "__main__":
    main()
