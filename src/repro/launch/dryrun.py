import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.
(Smoke tests / benches never import this module and see 1 device.)

Per cell this driver:
  1. builds the step function the shape dictates (train_step for train_4k,
     prefill_step for prefill_32k, serve_step for decode_*);
  2. jits it with explicit in/out shardings on the production mesh
     ((16,16)='data','model' single pod, (2,16,16)='pod','data','model'
     multi-pod) and ``.lower().compile()``s against ShapeDtypeStructs --
     no device allocation anywhere;
  3. prints ``compiled.memory_analysis()`` (proves the cell fits HBM) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline);
  4. parses collective ops out of ``compiled.as_text()`` and writes the
     full record to artifacts/dryrun/*.json for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all                  # 40-cell baseline
  python -m repro.launch.dryrun --all --multi-pod      # 512-chip pass
  ... [--policy mixed|fp4|posit8_0|bf16|fp32] [--attn-impl triangular]
      [--quantized-kv] [--decode-impl blocked|flash] [--opt-dtype posit8]
      [--paged [--pool-frac 0.25]]
      [--chunked-prefill [--prefill-chunk 256]] [--tag NAME]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, all_cells, get_config
from ..configs.base import ModelConfig, RunConfig, ShapeConfig
from ..core.policy import PrecisionPolicy
from ..models import zoo
from ..parallel import sharding as sh
from ..roofline import analysis as ra
from ..roofline.hw import TPU_V5E
from ..serve.engine import build_prefill_step, build_serve_step
from ..train.loop import build_train_step, init_state
from . import specs as sp
from .mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _batch_shardings(mesh, batch_sds):
    bp = sh.batch_pspec(mesh)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s):
        spec = [None] * len(s.shape)
        if s.shape and len(bp):
            want = bp[0] if isinstance(bp[0], tuple) else (bp[0],)
            got = []
            prod = 1
            for a in want:  # drop axes that don't divide (e.g. batch=1)
                if s.shape[0] % (prod * axes[a]) == 0:
                    got.append(a)
                    prod *= axes[a]
            if got:
                spec[0] = tuple(got) if len(got) > 1 else got[0]
        return jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*spec))
    return jax.tree.map(one, batch_sds)


def _policy(name: str) -> PrecisionPolicy:
    if name == "mixed":
        return PrecisionPolicy.paper_mixed()
    return PrecisionPolicy.uniform(name)


def _serve_params_sds(cfg: ModelConfig, policy: PrecisionPolicy,
                      policy_name: str):
    def build():
        params = zoo.init_model(jax.random.PRNGKey(0), cfg)
        if policy_name == "bf16":
            return jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params)
        if policy_name == "fp32":
            return params
        return zoo.pack_params(params, policy)
    return jax.eval_shape(build)


def _lower_one(cfg, shape, mesh, policy, policy_name, run_kw, quantized_kv):
    """Lower + compile one step program; return (compiled, t_lower, t_compile)."""
    t0 = time.perf_counter()
    if shape.kind == "train":
        run = RunConfig(qat=run_kw["qat"], precision_policy=policy_name,
                        opt_state_dtype=run_kw["opt_dtype"],
                        microbatch=run_kw["microbatch"],
                        grad_compression=run_kw["grad_compression"])
        state_sds = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, run))
        step_fn, shard_state = build_train_step(cfg, run, policy, mesh=mesh)
        state_sh = shard_state(state_sds)
        batch_sds = sp.batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch_sh = _batch_shardings(mesh, batch_sds)
        with sh.use_mesh(mesh):
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
    elif shape.kind == "prefill" and run_kw.get("chunked_prefill"):
        # chunked-prefill cell: the LAST chunk of an S-token prompt --
        # `chunk` query tokens against an (S - chunk)-token bf16 KV
        # carry, the largest step chunked paged prefill ever pays
        from ..serve.engine import build_prefill_chunk_step
        params_sds = _serve_params_sds(cfg, policy, policy_name)
        params_sh = sh.param_sharding_tree(mesh, params_sds)
        chunk = min(run_kw.get("prefill_chunk") or 256, shape.seq_len)
        in_sds = sp.chunk_prefill_specs(cfg, chunk, shape.seq_len - chunk)
        ctx_sh = sh.cache_sharding_tree(mesh, in_sds["ctx"], 1)
        tok_sh = _batch_shardings(mesh, in_sds["tokens"])
        start_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        fn = build_prefill_chunk_step(cfg, kv_group=policy.group_size)
        with sh.use_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(params_sh, tok_sh, ctx_sh, start_sh),
            ).lower(params_sds, in_sds["tokens"], in_sds["ctx"],
                    in_sds["start"])
    elif shape.kind == "prefill":
        params_sds = _serve_params_sds(cfg, policy, policy_name)
        params_sh = sh.param_sharding_tree(mesh, params_sds)
        batch_sds = sp.batch_specs(cfg, shape.global_batch, shape.seq_len,
                                   with_labels=False)
        batch_sh = _batch_shardings(mesh, batch_sds)
        fn = build_prefill_step(
            cfg, last_logit_only=run_kw.get("last_logit_only", False),
            quantized_kv=quantized_kv, kv_group=policy.group_size)
        with sh.use_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(params_sh, batch_sh),
            ).lower(params_sds, batch_sds)
    else:  # decode
        params_sds = _serve_params_sds(cfg, policy, policy_name)
        params_sh = sh.param_sharding_tree(mesh, params_sds)
        if run_kw.get("paged"):
            # continuous-batching cell: pool pages + page table instead
            # of the dense (B, max_len) cache; build_serve_step lowers
            # unchanged (the paged dispatch is cache-structure-driven)
            cache_sds = sp.paged_cache_specs(
                cfg, shape.global_batch, shape.seq_len,
                pool_frac=run_kw.get("pool_frac", 0.25),
                kv_group=policy.group_size)
        else:
            cache_sds = sp.cache_specs(cfg, shape.global_batch,
                                       shape.seq_len, quantized_kv,
                                       kv_group=policy.group_size)
        cache_sh = sh.cache_sharding_tree(mesh, cache_sds,
                                          shape.global_batch)
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_sh = _batch_shardings(mesh, tok_sds)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        pos_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        fn = build_serve_step(cfg)
        with sh.use_mesh(mesh):
            lowered = jax.jit(
                fn, in_shardings=(params_sh, tok_sh, cache_sh, pos_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            ).lower(params_sds, tok_sds, cache_sds, pos_sds)

    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    return compiled, t_lower, time.perf_counter() - t0


def _cost_dict(compiled):
    """``compiled.cost_analysis()`` returns a bare dict on newer jax and a
    one-element per-device list on 0.4.x -- normalize."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _cost_of(cfg, shape, mesh, policy, policy_name, run_kw, quantized_kv):
    compiled, tl, tc = _lower_one(cfg, shape, mesh, policy, policy_name,
                                  run_kw, quantized_kv)
    cost = _cost_dict(compiled)
    colls = ra.collective_stats(compiled.as_text())
    return cost, colls


def _layer_unit(cfg) -> int:
    """Smallest layer-count increment of the stacked scan."""
    return cfg.attn_every if cfg.family == "hybrid" else 1


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy_name: str = "mixed", quantized_kv: bool = False,
               opt_dtype: str = "posit8", attn_impl: str = None,
               remat: str = None, microbatch: int = 0,
               grad_compression: str = "none", qat: bool = True,
               seq_chunk: int = None, verbose: bool = True,
               extrapolate: bool = True, last_logit_only: bool = False,
               attn_scores_f32: bool = True, decode_impl: str = "blocked",
               paged: bool = False, pool_frac: float = 0.25,
               chunked_prefill: bool = False, prefill_chunk: int = 256):
    """Full-cell dry-run.

    ``extrapolate``: XLA's cost_analysis counts a while-loop (scan) body
    once regardless of trip count, so per-layer costs vanish from the
    L-layer scan.  We therefore also compile 1- and 2-unit variants of the
    same cell (cheap: tiny HLO) and extrapolate
    ``cost(L) = cost(1) + (L-1) * (cost(2) - cost(1))`` -- exact for a
    homogeneous stacked scan, still 100%% HLO-derived.  memory_analysis
    and the collective *schedule* come from the full-L compile.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # decode_impl "blocked" (the default) keeps quantized-KV decode on
    # the pure-XLA length-aware path, which lowers for the host compile
    # target; "flash" lowers the fused Pallas kernel (TPU runs)
    over = {"attn_impl": attn_impl or "triangular",
            "attn_scores_f32": attn_scores_f32,
            "decode_impl": decode_impl}
    if remat:
        over["remat"] = remat
    if seq_chunk:
        over["seq_chunk"] = seq_chunk
    elif shape.seq_len > 8192:
        # compile-time hygiene: cap the triangular unroll at 8 q-chunks
        # for long-prefill cells (identical FLOPs accounting, 4x smaller
        # HLO body on 1 CPU compile core)
        over["seq_chunk"] = shape.seq_len // 8
    cfg = dataclasses.replace(cfg, **over)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    policy = _policy(policy_name)
    run_kw = dict(qat=qat, opt_dtype=opt_dtype, microbatch=microbatch,
                  grad_compression=grad_compression,
                  last_logit_only=last_logit_only,
                  paged=paged, pool_frac=pool_frac,
                  chunked_prefill=chunked_prefill,
                  prefill_chunk=prefill_chunk)

    compiled, t_lower, t_compile = _lower_one(
        cfg, shape, mesh, policy, policy_name, run_kw, quantized_kv)
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    colls = ra.collective_stats(hlo)

    extrap = None
    unit = _layer_unit(cfg)
    if extrapolate and cfg.n_layers > 2 * unit:
        # probes UNROLL the layer stack (scan_layers=False) so per-layer
        # FLOPs are visible to cost_analysis; 1 and 2 units suffice.
        cfg1 = dataclasses.replace(cfg, n_layers=unit, scan_layers=False)
        cfg2 = dataclasses.replace(cfg, n_layers=2 * unit,
                                   scan_layers=False)
        c1, k1 = _cost_of(cfg1, shape, mesh, policy, policy_name,
                          run_kw, quantized_kv)
        c2, k2 = _cost_of(cfg2, shape, mesh, policy, policy_name,
                          run_kw, quantized_kv)
        steps = cfg.n_layers // unit
        def ext(a, b):
            return a + (steps - 1) * max(b - a, 0.0)
        cost = dict(cost)
        cost["flops"] = ext(c1.get("flops", 0.0), c2.get("flops", 0.0))
        cost["bytes accessed"] = ext(c1.get("bytes accessed", 0.0),
                                     c2.get("bytes accessed", 0.0))
        colls = dict(colls)
        for key in ("wire_bytes", "operand_bytes"):
            colls[key] = ext(k1.get(key, 0.0), k2.get(key, 0.0))
        extrap = {"unit_layers": unit,
                  "flops_1": c1.get("flops", 0.0),
                  "flops_2": c2.get("flops", 0.0)}

    terms = ra.roofline_terms(cost, colls, chips)
    wbits = {"fp4": 4.0, "posit8_0": 8.0, "posit16_1": 16.0,
             "bf16": 16.0, "fp32": 32.0}.get(policy_name, 4.5)
    summary = ra.summarize_cell(cfg, shape, terms, chips,
                                weight_bits=wbits,
                                quantized_kv=quantized_kv)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "chips": chips,
        "multi_pod": multi_pod, "policy": policy_name,
        "quantized_kv": quantized_kv, "opt_dtype": opt_dtype,
        "attn_impl": cfg.attn_impl, "remat": cfg.remat,
        "decode_impl": cfg.decode_impl,
        "paged": paged, "pool_frac": pool_frac if paged else None,
        "chunked_prefill": chunked_prefill,
        "prefill_chunk": (min(prefill_chunk, shape.seq_len)
                          if chunked_prefill else None),
        "grad_compression": grad_compression, "qat": qat,
        "microbatch": microbatch, "extrapolation": extrap,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_nonaliased_bytes": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost": {k: v for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": colls,
        "roofline": summary,
        "params_total": ra.total_param_count(cfg),
        "params_active": ra.active_param_count(cfg),
    }
    if verbose:
        print(f"--- {arch} x {shape_name} on {tuple(mesh.devices.shape)} "
              f"(policy={policy_name}) ---")
        print("memory_analysis:", mem)
        print("cost_analysis (layer-extrapolated): flops=%.3e bytes=%.3e" %
              (cost.get("flops", 0), cost.get("bytes accessed", 0)))
        print("collectives: count=%d wire_bytes/dev=%.3e" %
              (colls["count"], colls["wire_bytes"]))
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs "
              "dominant=%s fraction=%.3f" %
              (summary["t_compute_s"], summary["t_memory_s"],
               summary["t_collective_s"], summary["dominant"],
               summary["roofline_fraction"]))
        print("lower=%.1fs compile=%.1fs" % (t_lower, t_compile))
    return record


def save_record(record, tag: str = ""):
    os.makedirs(ART_DIR, exist_ok=True)
    mesh_tag = "x".join(map(str, record["mesh"]))
    name = f"{record['arch']}__{record['shape']}__{mesh_tag}"
    if tag:
        name += f"__{tag}"
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="mixed")
    ap.add_argument("--quantized-kv", action="store_true")
    ap.add_argument("--opt-dtype", default="posit8")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--decode-impl", default="blocked",
                    choices=["blocked", "flash"])
    ap.add_argument("--paged", action="store_true",
                    help="decode cells lower the paged-KV (continuous "
                         "batching) cache plane instead of the dense one")
    ap.add_argument("--pool-frac", type=float, default=0.25,
                    help="paged pool capacity as a fraction of the "
                         "worst-case batch*max_len token count")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="prefill cells lower ONE chunk-prefill step "
                         "(the last chunk of an S-token prompt) instead "
                         "of the monolithic prefill")
    ap.add_argument("--prefill-chunk", type=int, default=256,
                    help="chunk width of the --chunked-prefill cell")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--seq-chunk", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--no-qat", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the 1/2-layer probe compiles (multi-pod "
                         "pass: sharding proof only; roofline is single-pod)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, sname, cfg, shp, runnable in all_cells():
            if runnable:
                cells.append((arch, sname))
            else:
                print(f"SKIP {arch} x {sname}: long_500k needs "
                      f"sub-quadratic attention (see DESIGN.md)")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, sname in cells:
        if args.skip_existing:
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            name = f"{arch}__{sname}__{mesh_tag}"
            if args.tag:
                name += f"__{args.tag}"
            if os.path.exists(os.path.join(ART_DIR, name + ".json")):
                print("skip (exists):", name)
                continue
        try:
            rec = lower_cell(
                arch, sname, multi_pod=args.multi_pod,
                policy_name=args.policy, quantized_kv=args.quantized_kv,
                opt_dtype=args.opt_dtype, attn_impl=args.attn_impl,
                remat=args.remat, microbatch=args.microbatch,
                grad_compression=args.grad_compression,
                qat=not args.no_qat, seq_chunk=args.seq_chunk,
                extrapolate=not args.no_extrapolate,
                decode_impl=args.decode_impl,
                paged=args.paged, pool_frac=args.pool_frac,
                chunked_prefill=args.chunked_prefill,
                prefill_chunk=args.prefill_chunk)
            path = save_record(rec, args.tag)
            print("saved", path)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, sname, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells")


if __name__ == "__main__":
    main()
