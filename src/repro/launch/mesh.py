"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips ('data','model').
Multi-pod: 2x16x16 = 512 chips ('pod','data','model') -- the 'pod' axis is
the DCN dimension; batch shards across it (pure DP between pods), FSDP
stays within-pod on ICI (see parallel/sharding.py).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
