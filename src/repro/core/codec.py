"""Codec registry -- the single dispatch point of the packed-weight data
plane.

Every consumer of a ``FormatSpec`` (QAT fake-quant, the packed serving
plane, Pallas kernels, gradient/optimizer compression) goes through a
``Codec`` obtained from :func:`get_codec`.  A codec owns the three
operations of the RMMEC datapath:

  encode   : float -> raw int32 codes (the format's bit patterns)
  decode   : raw codes -> float (NaR/NaN codes -> 0.0, the hardware
             exception path: the paper's input-processing stage feeds
             zero to the accumulator on exceptional operands)
  quantize : decode . encode -- round-trip onto the format's value grid

Two implementations back each codec and the *codec* picks between them;
callers never do:

  * table path      -- exact ``searchsorted`` over the enumerated code
    values (``formats.encode`` / ``formats.code_values``).  Used for
    small concrete tensors where exactness and debuggability win.
  * algorithmic path -- branch-free integer bit manipulation
    (``formats.encode_bits`` / ``formats.decode_bits``).  Used under
    tracing (jit / Pallas kernel bodies, where a 64K-entry gather would
    thrash VMEM) and for large tensors (where a table broadcast would
    blow memory).  Validated code-for-code identical to the table path
    by tests/test_formats.py.

New format kinds register with :func:`register_codec`; ``FormatSpec.kind``
is the registry key, so adding a kind touches this module only -- no
consumer grows another ``if spec.kind == ...`` fork.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Type

import jax
import jax.numpy as jnp
import numpy as np

from . import formats as fmt
from .formats import FormatSpec

__all__ = ["Codec", "get_codec", "register_codec", "encode", "decode",
           "quantize"]

_REGISTRY: Dict[str, Type["Codec"]] = {}

# Above this many elements the table path's gather/broadcast costs more
# than the branch-free integer pipeline; below it, exactness is free.
_TABLE_MAX_ELEMS = 1 << 16


def register_codec(kind: str) -> Callable[[Type["Codec"]], Type["Codec"]]:
    """Class decorator: route ``FormatSpec.kind == kind`` to this codec."""
    def deco(cls: Type["Codec"]) -> Type["Codec"]:
        _REGISTRY[kind] = cls
        return cls
    return deco


@functools.lru_cache(maxsize=None)
def get_codec(spec: FormatSpec) -> "Codec":
    """The codec for ``spec`` (cached; codecs are stateless)."""
    try:
        cls = _REGISTRY[spec.kind]
    except KeyError:
        raise ValueError(f"no codec registered for format kind {spec.kind!r}"
                         ) from None
    return cls(spec)


class Codec:
    """encode/decode/quantize for one ``FormatSpec``.

    Subclasses provide the algorithmic primitives; the base class owns
    the table path and the internal table-vs-algorithmic dispatch.
    """

    def __init__(self, spec: FormatSpec):
        self.spec = spec

    # -- internal dispatch --------------------------------------------------
    def _prefer_table(self, x) -> bool:
        """Table path only for small *concrete* arrays: anything traced
        (jit, vmap, Pallas kernel bodies) takes the branch-free path."""
        if isinstance(x, jax.core.Tracer):
            return False
        size = getattr(x, "size", None)
        return size is not None and size <= _TABLE_MAX_ELEMS

    # -- algorithmic primitives (overridden per kind) -----------------------
    def _encode_alg(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _decode_alg(self, codes: jax.Array, dtype) -> jax.Array:
        raise NotImplementedError

    # -- table primitives ---------------------------------------------------
    @functools.cached_property
    def _decode_table(self) -> np.ndarray:
        """Value of every code with the hardware exception semantics
        (NaR/NaN codes decode to 0.0)."""
        vals = fmt.code_values(self.spec)
        return np.where(np.isfinite(vals), vals, 0.0).astype(np.float32)

    # -- public API ---------------------------------------------------------
    def encode(self, x: jax.Array) -> jax.Array:
        """float -> nearest raw code (int32); NaN -> NaR; saturating."""
        if self._prefer_table(x):
            return fmt.encode(self.spec, x)
        return self._encode_alg(x)

    def decode(self, codes: jax.Array, dtype=jnp.float32) -> jax.Array:
        """Raw codes -> float.  NaR/NaN codes -> 0 on both paths (codes
        produced by ``encode`` never contain them)."""
        if self._prefer_table(codes):
            table = jnp.asarray(self._decode_table)
            idx = codes.astype(jnp.int32) & (self.spec.ncodes - 1)
            return table[idx].astype(dtype)
        return self._decode_alg(codes, dtype)

    def quantize(self, x: jax.Array) -> jax.Array:
        """Round-trip onto the format's value grid (same dtype out)."""
        return self.decode(self.encode(x), dtype=jnp.float32).astype(x.dtype)


@register_codec("posit")
class PositCodec(Codec):
    def _encode_alg(self, x):
        return fmt.encode_posit_bits(x, self.spec.bits, self.spec.es)

    def _decode_alg(self, codes, dtype):
        return fmt.decode_posit_bits(codes, self.spec.bits, self.spec.es,
                                     dtype)


@register_codec("minifloat")
class MinifloatCodec(Codec):
    def _encode_alg(self, x):
        return fmt.encode_minifloat_bits(x, self.spec.ebits, self.spec.mbits,
                                         self.spec.has_nan)

    def _decode_alg(self, codes, dtype):
        return fmt.decode_minifloat_bits(codes, self.spec.ebits,
                                         self.spec.mbits, dtype,
                                         self.spec.has_nan)


@register_codec("fixed")
class FixedCodec(Codec):
    def _encode_alg(self, x):
        spec = self.spec
        q = jnp.clip(jnp.round(x.astype(jnp.float32) * (1 << spec.frac_bits)),
                     -(spec.ncodes // 2), spec.ncodes // 2 - 1)
        return q.astype(jnp.int32) & (spec.ncodes - 1)

    def _decode_alg(self, codes, dtype):
        spec = self.spec
        c = codes.astype(jnp.int32) & (spec.ncodes - 1)
        c = jnp.where(c >= spec.ncodes // 2, c - spec.ncodes, c)
        return c.astype(dtype) / (1 << spec.frac_bits)


@register_codec("native")
class NativeCodec(Codec):
    """Native JAX dtypes: encode/decode are dtype casts, no code table."""

    def encode(self, x):
        return x.astype(self.spec.dtype)

    def decode(self, codes, dtype=jnp.float32):
        return codes.astype(dtype)

    def quantize(self, x):
        return x.astype(self.spec.dtype).astype(x.dtype)


# -- module-level conveniences (mirror the formats.py free functions) -------

def encode(spec: FormatSpec, x: jax.Array) -> jax.Array:
    return get_codec(spec).encode(x)


def decode(spec: FormatSpec, codes: jax.Array, dtype=jnp.float32) -> jax.Array:
    return get_codec(spec).decode(codes, dtype)


def quantize(spec: FormatSpec, x: jax.Array) -> jax.Array:
    return get_codec(spec).quantize(x)
