"""Exact quire accumulation oracle.

The XR-NPE accumulates posit products in a quire -- a wide fixed-point
register that makes the dot product exact up to the single final rounding.
This module is the *bit-exact reference* used to validate both the pure-jnp
GEMM reference and the Pallas ``quire_dot`` kernel: every posit value is a
dyadic rational ``mant * 2**scale`` so products and sums are exact in
unbounded Python integers.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .formats import FormatSpec, code_values

__all__ = ["value_as_fixed", "quire_dot_exact", "quire_matmul_exact"]


def value_as_fixed(spec: FormatSpec, code: int, lsb_pow: int) -> int:
    """Value of ``code`` as an integer multiple of ``2**lsb_pow`` (exact)."""
    v = float(code_values(spec)[code & (spec.ncodes - 1)])
    if np.isnan(v):
        return 0
    frac = v * (2.0 ** -lsb_pow)
    out = int(round(frac))
    assert out == frac, f"lsb 2^{lsb_pow} too coarse for {spec.name} value {v}"
    return out


def _min_lsb(spec: FormatSpec) -> int:
    """Power p such that every value of ``spec`` is a multiple of 2**p."""
    vals = code_values(spec)
    finite = vals[np.isfinite(vals) & (vals != 0)]
    # every posit/minifloat value is mant/2^F * 2^scale; brute-force p.
    for p in range(0, -200, -1):
        scaled = finite * (2.0 ** -p)
        if np.all(scaled == np.round(scaled)):
            return p
    raise ValueError(spec)


def quire_dot_exact(spec: FormatSpec, a_codes, b_codes) -> float:
    """Exact dot product of two 1-D code vectors, one final f64 rounding."""
    a_codes = np.asarray(a_codes).ravel()
    b_codes = np.asarray(b_codes).ravel()
    assert a_codes.shape == b_codes.shape
    p = _min_lsb(spec)
    av = [value_as_fixed(spec, int(c), p) for c in a_codes]
    bv = [value_as_fixed(spec, int(c), p) for c in b_codes]
    acc = 0
    for x, y in zip(av, bv):
        acc += x * y  # exact: the quire
    return float(acc) * (2.0 ** (2 * p))


def quire_matmul_exact(spec: FormatSpec, a_codes, b_codes) -> np.ndarray:
    """Exact [M,K] x [K,N] over codes -> f64 result (reference only)."""
    a_codes = np.asarray(a_codes)
    b_codes = np.asarray(b_codes)
    m, k = a_codes.shape
    k2, n = b_codes.shape
    assert k == k2
    p = _min_lsb(spec)
    table = code_values(spec).astype(np.float64)
    table = np.where(np.isnan(table), 0.0, table)
    ai = np.round(table[a_codes & (spec.ncodes - 1)] * 2.0 ** -p).astype(object)
    bi = np.round(table[b_codes & (spec.ncodes - 1)] * 2.0 ** -p).astype(object)
    ai = np.vectorize(int, otypes=[object])(ai)
    bi = np.vectorize(int, otypes=[object])(bi)
    out = np.empty((m, n), np.float64)
    for i in range(m):
        for j in range(n):
            acc = 0
            for t in range(k):
                acc += ai[i, t] * bi[t, j]
            out[i, j] = float(acc) * (2.0 ** (2 * p))
    return out
