"""Quantization machinery of the paper (eq. 3-7).

Three pieces, exactly as the paper stages them:

  * entropy-based uniform quantization with learned saturation thresholds
    (eq. 3-5), used for the fixed-point comparison arm;
  * PACT parameterized clipping activation (eq. 6-7) with a trainable
    clipping threshold ``alpha``;
  * format fake-quantization: round a float tensor onto the FP4/posit value
    grid through a (power-of-two by default) scale, with a straight-through
    estimator so QAT gradients flow.  "The activations are retained with
    particular precision across all layers, while computations remain in
    FP-arithmetic" -- i.e. forward quantizes values, compute stays float,
    which is precisely what fake-quant does.

Scales are power-of-two by default: a po2 scale is an exponent shift in the
XR-NPE datapath (free in the scale-accumulate stage) and keeps decode exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import codec as codec_mod
from . import formats as fmt
from .formats import FormatSpec

__all__ = [
    "entropy_scale", "uniform_quantize", "pact", "pact_quantize",
    "format_scale", "group_scales", "expand_group_scales", "fake_quant",
    "fake_quant_stochastic", "max_finite",
]


@functools.lru_cache(maxsize=None)
def max_finite(spec: FormatSpec) -> float:
    if spec.kind == "native":
        return float(jnp.finfo(spec.dtype).max)
    vals = fmt.code_values(spec)
    return float(np.nanmax(np.abs(vals[np.isfinite(vals)])))


# ---------------------------------------------------------------------------
# eq. 3-5: entropy-based uniform quantization with saturation thresholds
# ---------------------------------------------------------------------------

def entropy_scale(w: jax.Array, n: int) -> jax.Array:
    """eq. (3): scale k = mean(|W|) * (2^n - 1) / 2^(n-1)."""
    return jnp.mean(jnp.abs(w)) * ((2.0 ** n - 1.0) / (2.0 ** (n - 1)))


def uniform_quantize(w: jax.Array, n: int, w_l: jax.Array, w_h: jax.Array,
                     k: Optional[jax.Array] = None) -> jax.Array:
    """eq. (4)+(5): clip to the learned [w_l, w_h] window, quantize to 2^n
    levels, dequantize.  Thresholds adapt to the weight distribution rather
    than the conventional [-1, 1]."""
    if k is None:
        k = entropy_scale(w, n)
    levels = 2.0 ** n - 1.0
    w_hat = jnp.round((jnp.clip(w / k, w_l, w_h) - w_l) * (levels / (w_h - w_l)))
    return w_hat * ((w_h - w_l) / levels) + w_l


# ---------------------------------------------------------------------------
# eq. 6-7: PACT
# ---------------------------------------------------------------------------

def pact(x: jax.Array, alpha: jax.Array) -> jax.Array:
    """eq. (6): y = 0.5 (|x| - |x - alpha| + alpha) == clip(x, 0, alpha)."""
    return 0.5 * (jnp.abs(x) - jnp.abs(x - alpha) + alpha)


@jax.custom_vjp
def _pact_quant_core(y: jax.Array, alpha: jax.Array, n: int) -> jax.Array:
    levels = 2.0 ** n - 1.0
    return jnp.round(y * (levels / alpha)) * (alpha / levels)


def _pact_quant_fwd(y, alpha, n):
    return _pact_quant_core(y, alpha, n), (y, alpha)


def _pact_quant_bwd(res, g):
    y, alpha = res
    # STE through the rounding; d/dalpha follows PACT: grad flows to alpha
    # where the input saturated (y == alpha after clipping).
    saturated = (y >= alpha).astype(g.dtype)
    return (g * (1.0 - saturated),
            jnp.sum(g * saturated).astype(alpha.dtype), None)


_pact_quant_core.defvjp(_pact_quant_fwd, _pact_quant_bwd)


def pact_quantize(x: jax.Array, alpha: jax.Array, n: int) -> jax.Array:
    """eq. (6)+(7) with trainable alpha (PACT backward rule)."""
    return _pact_quant_core(pact(x, alpha), alpha, n)


# ---------------------------------------------------------------------------
# Format fake-quantization with STE (the QAT forward of the paper)
# ---------------------------------------------------------------------------

def format_scale(spec: FormatSpec, w: jax.Array, method: str = "auto",
                 axis=None) -> jax.Array:
    """Per-tensor (axis=None) or per-channel scale mapping w into the
    format's dynamic range.

    auto                : posit -> posit_rms, others -> absmax_po2.
                          Posits have tapered precision densest near +-1;
                          absmax-scaling a gaussian tensor to posit16's
                          maxpos (2^28) parks every value in the
                          regime-dominated tail (measured 43% rms error vs
                          0.1% with rms centering).  Minifloats have
                          uniform relative precision, so absmax is right.
    absmax / absmax_po2 : absmax(w) -> largest finite value (po2 = rounded
                          to a power of two; exponent-shift-only in HW).
    entropy             : eq. (3) (paper's scheme for the FxP arm).
    posit_rms           : RMS(w) -> 1.0.
    """
    if method == "auto":
        method = "posit_rms" if spec.kind == "posit" else "absmax_po2"
    if method == "entropy":
        return entropy_scale(w, spec.bits)
    if method in ("absmax", "absmax_po2"):
        a = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
        s = a / max_finite(spec)
        if method == "absmax_po2":
            s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 1e-30))))
        return jnp.maximum(s, 1e-30)
    if method == "posit_rms":
        r = jnp.sqrt(jnp.mean(jnp.square(w), axis=axis,
                              keepdims=axis is not None))
        s = jnp.exp2(jnp.round(jnp.log2(jnp.maximum(r, 1e-30))))
        return jnp.maximum(s, 1e-30)
    raise ValueError(method)


def _resolve_method(spec: FormatSpec, method: str) -> str:
    if method == "auto":
        return "posit_rms" if spec.kind == "posit" else "absmax_po2"
    return method


def group_scales(spec: FormatSpec, w: jax.Array, group_size: Optional[int],
                 method: str = "auto") -> jax.Array:
    """Per-(K-group, out-channel) scales for ``w`` (..., K, N): block-wise
    scaling along the contraction dim, the accuracy lever that makes
    4-bit formats usable (fine groups track local dynamic range).

    Returns (..., G, N) with G = ceil(K / group_size); ``group_size``
    None/0 or >= K degenerates to per-channel (G = 1, the ``group=K``
    special case -- bitwise identical to ``format_scale(axis=-2)``).

    Rows past K (when K is not a multiple of the group) never influence
    a group's statistic: absmax ignores zero padding; rms/entropy divide
    by each group's real row count.
    """
    *lead, k, n = w.shape
    if not group_size or group_size >= k:
        s = format_scale(spec, w, method, axis=-2)
        # entropy (and any scalar-returning method) broadcasts to the
        # per-channel (..., 1, N) layout the packed plane stores
        return jnp.broadcast_to(jnp.asarray(s), tuple(lead) + (1, n))
    method = _resolve_method(spec, method)
    g = int(group_size)
    ngroups = -(-k // g)
    kp = ngroups * g
    if kp != k:
        w = jnp.pad(w, [(0, 0)] * len(lead) + [(0, kp - k), (0, 0)])
    wg = w.reshape(tuple(lead) + (ngroups, g, n))
    counts = jnp.clip(k - jnp.arange(ngroups) * g, 1, g).astype(jnp.float32)
    counts = counts.reshape((1,) * len(lead) + (ngroups, 1))
    if method == "entropy":
        mean_abs = jnp.sum(jnp.abs(wg), axis=-2) / counts
        s = mean_abs * ((2.0 ** spec.bits - 1.0) / (2.0 ** (spec.bits - 1)))
        return jnp.maximum(s, 1e-30)
    if method in ("absmax", "absmax_po2"):
        s = jnp.max(jnp.abs(wg), axis=-2) / max_finite(spec)
        if method == "absmax_po2":
            s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 1e-30))))
        return jnp.maximum(s, 1e-30)
    if method == "posit_rms":
        r = jnp.sqrt(jnp.sum(jnp.square(wg), axis=-2) / counts)
        s = jnp.exp2(jnp.round(jnp.log2(jnp.maximum(r, 1e-30))))
        return jnp.maximum(s, 1e-30)
    raise ValueError(method)


def expand_group_scales(scales: jax.Array, group_size: Optional[int],
                        k: int) -> jax.Array:
    """(..., G, N) group scales -> per-row multiplier covering ``k`` rows.
    G == 1 (per-channel) returns as-is (it broadcasts); otherwise each
    group row is repeated ``group_size`` times and cropped to ``k``."""
    if scales.shape[-2] == 1:
        return scales
    return jnp.repeat(scales, int(group_size), axis=-2)[..., :k, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fake_quant_core(spec: FormatSpec, x, scale):
    # the codec picks the algorithmic (branch-free) round-trip under jit:
    # no table gathers, no wide broadcasts -- safe on billion-element
    # weight tensors
    return codec_mod.quantize(spec, x / scale) * scale


def _fq_fwd(spec, x, scale):
    return _fake_quant_core(spec, x, scale), (x, scale)


def _fq_bwd(spec, res, g):
    x, scale = res
    # clipped STE: identity inside the representable range, zero outside
    lim = max_finite(spec) * scale
    inside = (jnp.abs(x) <= lim).astype(g.dtype)
    gx = g * inside
    return gx, jnp.zeros_like(scale)


_fake_quant_core.defvjp(_fq_fwd, _fq_bwd)


def fake_quant(spec: FormatSpec, x: jax.Array,
               scale: Optional[jax.Array] = None,
               method: str = "auto",
               group_size: Optional[int] = None) -> jax.Array:
    """Quantize-dequantize ``x`` onto ``spec``'s grid with an STE backward.

    This is the QAT forward pass: the value distribution the low-bit
    datapath will see, with master weights staying fp32.  With
    ``group_size`` set (and ``x.ndim >= 2``), scales are per K-group per
    out-channel -- the same grouping the packed serving plane uses, so
    QAT trains against exactly the grid it serves with.
    """
    if spec.kind == "native":
        return x.astype(spec.dtype).astype(x.dtype)
    if scale is None:
        if group_size and x.ndim >= 2:
            gs = group_scales(spec, x, group_size, method)
            scale = expand_group_scales(gs, group_size, x.shape[-2])
        else:
            scale = format_scale(spec, x, method)
        scale = jax.lax.stop_gradient(scale)
    return _fake_quant_core(spec, x, scale)


def fake_quant_stochastic(spec: FormatSpec, x: jax.Array, key: jax.Array,
                          scale: Optional[jax.Array] = None) -> jax.Array:
    """Stochastic-rounding variant (used for gradient compression): round
    up/down with probability proportional to the distance, unbiased in
    expectation."""
    if scale is None:
        scale = format_scale(spec, x, "absmax_po2")
    y = x / scale
    lo = fmt.quantize(spec, y)  # RNE landing point
    # find the neighbour on the other side of y
    eps = jnp.where(y > lo, 1.0, -1.0)
    svals, scodes, _ = fmt._encode_tables(spec)
    svals_j = jnp.asarray(svals.astype(np.float32))
    idx = jnp.searchsorted(svals_j, lo.astype(jnp.float32))
    nxt = svals_j[jnp.clip(idx + eps.astype(jnp.int32), 0, len(svals) - 1)]
    gap = jnp.abs(nxt - lo)
    p_up = jnp.where(gap > 0, jnp.abs(y - lo) / jnp.maximum(gap, 1e-30), 0.0)
    u = jax.random.uniform(key, y.shape)
    out = jnp.where(u < p_up, nxt, lo)
    return out * scale
