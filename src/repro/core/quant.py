"""Quantization machinery of the paper (eq. 3-7).

Three pieces, exactly as the paper stages them:

  * entropy-based uniform quantization with learned saturation thresholds
    (eq. 3-5), used for the fixed-point comparison arm;
  * PACT parameterized clipping activation (eq. 6-7) with a trainable
    clipping threshold ``alpha``;
  * format fake-quantization: round a float tensor onto the FP4/posit value
    grid through a (power-of-two by default) scale, with a straight-through
    estimator so QAT gradients flow.  "The activations are retained with
    particular precision across all layers, while computations remain in
    FP-arithmetic" -- i.e. forward quantizes values, compute stays float,
    which is precisely what fake-quant does.

Scales are power-of-two by default: a po2 scale is an exponent shift in the
XR-NPE datapath (free in the scale-accumulate stage) and keeps decode exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import formats as fmt
from .formats import FormatSpec

__all__ = [
    "entropy_scale", "uniform_quantize", "pact", "pact_quantize",
    "format_scale", "fake_quant", "fake_quant_stochastic", "max_finite",
]


@functools.lru_cache(maxsize=None)
def max_finite(spec: FormatSpec) -> float:
    if spec.kind == "native":
        return float(jnp.finfo(spec.dtype).max)
    vals = fmt.code_values(spec)
    return float(np.nanmax(np.abs(vals[np.isfinite(vals)])))


# ---------------------------------------------------------------------------
# eq. 3-5: entropy-based uniform quantization with saturation thresholds
# ---------------------------------------------------------------------------

def entropy_scale(w: jax.Array, n: int) -> jax.Array:
    """eq. (3): scale k = mean(|W|) * (2^n - 1) / 2^(n-1)."""
    return jnp.mean(jnp.abs(w)) * ((2.0 ** n - 1.0) / (2.0 ** (n - 1)))


def uniform_quantize(w: jax.Array, n: int, w_l: jax.Array, w_h: jax.Array,
                     k: Optional[jax.Array] = None) -> jax.Array:
    """eq. (4)+(5): clip to the learned [w_l, w_h] window, quantize to 2^n
    levels, dequantize.  Thresholds adapt to the weight distribution rather
    than the conventional [-1, 1]."""
    if k is None:
        k = entropy_scale(w, n)
    levels = 2.0 ** n - 1.0
    w_hat = jnp.round((jnp.clip(w / k, w_l, w_h) - w_l) * (levels / (w_h - w_l)))
    return w_hat * ((w_h - w_l) / levels) + w_l


# ---------------------------------------------------------------------------
# eq. 6-7: PACT
# ---------------------------------------------------------------------------

def pact(x: jax.Array, alpha: jax.Array) -> jax.Array:
    """eq. (6): y = 0.5 (|x| - |x - alpha| + alpha) == clip(x, 0, alpha)."""
    return 0.5 * (jnp.abs(x) - jnp.abs(x - alpha) + alpha)


@jax.custom_vjp
def _pact_quant_core(y: jax.Array, alpha: jax.Array, n: int) -> jax.Array:
    levels = 2.0 ** n - 1.0
    return jnp.round(y * (levels / alpha)) * (alpha / levels)


def _pact_quant_fwd(y, alpha, n):
    return _pact_quant_core(y, alpha, n), (y, alpha)


def _pact_quant_bwd(res, g):
    y, alpha = res
    # STE through the rounding; d/dalpha follows PACT: grad flows to alpha
    # where the input saturated (y == alpha after clipping).
    saturated = (y >= alpha).astype(g.dtype)
    return (g * (1.0 - saturated),
            jnp.sum(g * saturated).astype(alpha.dtype), None)


_pact_quant_core.defvjp(_pact_quant_fwd, _pact_quant_bwd)


def pact_quantize(x: jax.Array, alpha: jax.Array, n: int) -> jax.Array:
    """eq. (6)+(7) with trainable alpha (PACT backward rule)."""
    return _pact_quant_core(pact(x, alpha), alpha, n)


# ---------------------------------------------------------------------------
# Format fake-quantization with STE (the QAT forward of the paper)
# ---------------------------------------------------------------------------

def format_scale(spec: FormatSpec, w: jax.Array, method: str = "auto",
                 axis=None) -> jax.Array:
    """Per-tensor (axis=None) or per-channel scale mapping w into the
    format's dynamic range.

    auto                : posit -> posit_rms, others -> absmax_po2.
                          Posits have tapered precision densest near +-1;
                          absmax-scaling a gaussian tensor to posit16's
                          maxpos (2^28) parks every value in the
                          regime-dominated tail (measured 43% rms error vs
                          0.1% with rms centering).  Minifloats have
                          uniform relative precision, so absmax is right.
    absmax / absmax_po2 : absmax(w) -> largest finite value (po2 = rounded
                          to a power of two; exponent-shift-only in HW).
    entropy             : eq. (3) (paper's scheme for the FxP arm).
    posit_rms           : RMS(w) -> 1.0.
    """
    if method == "auto":
        method = "posit_rms" if spec.kind == "posit" else "absmax_po2"
    if method == "entropy":
        return entropy_scale(w, spec.bits)
    if method in ("absmax", "absmax_po2"):
        a = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
        s = a / max_finite(spec)
        if method == "absmax_po2":
            s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 1e-30))))
        return jnp.maximum(s, 1e-30)
    if method == "posit_rms":
        r = jnp.sqrt(jnp.mean(jnp.square(w), axis=axis,
                              keepdims=axis is not None))
        s = jnp.exp2(jnp.round(jnp.log2(jnp.maximum(r, 1e-30))))
        return jnp.maximum(s, 1e-30)
    raise ValueError(method)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fake_quant_core(spec: FormatSpec, x, scale):
    # algorithmic (branch-free) round-trip: no table gathers, no wide
    # broadcasts -- safe on billion-element weight tensors
    return fmt.quantize_bits(spec, x / scale) * scale


def _fq_fwd(spec, x, scale):
    return _fake_quant_core(spec, x, scale), (x, scale)


def _fq_bwd(spec, res, g):
    x, scale = res
    # clipped STE: identity inside the representable range, zero outside
    lim = max_finite(spec) * scale
    inside = (jnp.abs(x) <= lim).astype(g.dtype)
    gx = g * inside
    return gx, jnp.zeros_like(scale)


_fake_quant_core.defvjp(_fq_fwd, _fq_bwd)


def fake_quant(spec: FormatSpec, x: jax.Array,
               scale: Optional[jax.Array] = None,
               method: str = "auto") -> jax.Array:
    """Quantize-dequantize ``x`` onto ``spec``'s grid with an STE backward.

    This is the QAT forward pass: the value distribution the low-bit
    datapath will see, with master weights staying fp32.
    """
    if spec.kind == "native":
        return x.astype(spec.dtype).astype(x.dtype)
    if scale is None:
        scale = jax.lax.stop_gradient(format_scale(spec, x, method))
    return _fake_quant_core(spec, x, scale)


def fake_quant_stochastic(spec: FormatSpec, x: jax.Array, key: jax.Array,
                          scale: Optional[jax.Array] = None) -> jax.Array:
    """Stochastic-rounding variant (used for gradient compression): round
    up/down with probability proportional to the distance, unbiased in
    expectation."""
    if scale is None:
        scale = format_scale(spec, x, "absmax_po2")
    y = x / scale
    lo = fmt.quantize(spec, y)  # RNE landing point
    # find the neighbour on the other side of y
    eps = jnp.where(y > lo, 1.0, -1.0)
    svals, scodes, _ = fmt._encode_tables(spec)
    svals_j = jnp.asarray(svals.astype(np.float32))
    idx = jnp.searchsorted(svals_j, lo.astype(jnp.float32))
    nxt = svals_j[jnp.clip(idx + eps.astype(jnp.int32), 0, len(svals) - 1)]
    gap = jnp.abs(nxt - lo)
    p_up = jnp.where(gap > 0, jnp.abs(y - lo) / jnp.maximum(gap, 1e-30), 0.0)
    u = jax.random.uniform(key, y.shape)
    out = jnp.where(u < p_up, nxt, lo)
    return out * scale
