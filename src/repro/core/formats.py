"""Number formats of the XR-NPE SIMD datapath.

The paper's engine supports, selected at runtime by ``prec_sel``:

  * HFP4      -- 4-bit minifloat e2m1 (sign / 2 exp / 1 mantissa), no inf/NaN
  * Posit(4,1)
  * Posit(8,0)
  * Posit(16,1)

plus the comparison formats used in its accuracy studies (FP8 e4m3, BF16,
FP16, FP32, fixed-point).  A format here is *not* a JAX dtype: a tensor in
format ``f`` is an integer tensor of raw codes (``int32`` holding
``f.bits``-bit patterns) together with the ``FormatSpec``.  ``decode`` maps
codes to float32 values, ``encode`` maps float32 to the nearest code
(round-to-nearest, ties-to-even-code -- the posit-standard rounding, which
coincides with IEEE RNE for minifloats), and ``quantize = decode . encode``.

This module is the *primitive* layer: two cross-validated implementations
of every codec operation, with no opinion about which to use --

  * table-based (``encode`` / ``decode``): enumerate all ``2^bits`` code
    values with an exact numpy scalar decoder, sort, and use
    ``searchsorted`` -- exact and simple;
  * algorithmic (``encode_bits`` / ``decode_bits``): branch-free integer
    bit manipulation, usable inside Pallas kernels where a 64K-entry
    gather would thrash VMEM, and on giant tensors where a table
    broadcast would blow memory.  This mirrors the paper's RMMEC decode
    circuitry: the regime/exponent extraction is the "exponent
    processing" half and the mantissa assembly the
    reconfigurable-multiplier half.

The choice between them lives in ONE place: the codec registry
(``core.codec``).  Consumers -- QAT, the packed serving plane, kernels,
gradient/optimizer compression -- call ``codec.encode/decode/quantize``
and never pick a path; only this module's tests and the codec registry
itself touch the per-path functions directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FormatSpec", "FORMATS", "FP4", "POSIT4", "POSIT8", "POSIT16",
    "FP8_E4M3", "FP8_E5M2", "FXP4", "FXP8", "BF16", "FP16", "FP32",
    "decode", "encode", "quantize", "code_values", "nar_code",
    "decode_posit_bits", "decode_minifloat_bits", "bits_per_value",
    "simd_lanes", "format_by_name", "storage_bits",
]


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """A (de)codable number format.

    kind:
      'posit'     -- posit(bits, es); NaR at 1000...0
      'minifloat' -- sign/ebits/mbits, subnormals, saturating (no inf);
                     NaN at the all-ones code only if ``has_nan``
      'fixed'     -- two's-complement fixed point with ``frac_bits``
      'native'    -- a JAX dtype (bf16/fp16/fp32); encode = bitcast
    """

    name: str
    bits: int
    kind: str
    es: int = 0
    ebits: int = 0
    mbits: int = 0
    has_nan: bool = False
    frac_bits: int = 0
    dtype: Optional[str] = None

    @property
    def ncodes(self) -> int:
        return 1 << self.bits

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


# --- the paper's formats -------------------------------------------------
FP4 = FormatSpec("fp4", 4, "minifloat", ebits=2, mbits=1)
POSIT4 = FormatSpec("posit4_1", 4, "posit", es=1)
POSIT8 = FormatSpec("posit8_0", 8, "posit", es=0)
POSIT16 = FormatSpec("posit16_1", 16, "posit", es=1)
# --- comparison formats used by the paper's accuracy figures -------------
FP8_E4M3 = FormatSpec("fp8_e4m3", 8, "minifloat", ebits=4, mbits=3, has_nan=True)
FP8_E5M2 = FormatSpec("fp8_e5m2", 8, "minifloat", ebits=5, mbits=2, has_nan=True)
FXP4 = FormatSpec("fxp4", 4, "fixed", frac_bits=2)
FXP8 = FormatSpec("fxp8", 8, "fixed", frac_bits=4)
BF16 = FormatSpec("bf16", 16, "native", dtype="bfloat16")
FP16 = FormatSpec("fp16", 16, "native", dtype="float16")
FP32 = FormatSpec("fp32", 32, "native", dtype="float32")

FORMATS = {
    f.name: f
    for f in (FP4, POSIT4, POSIT8, POSIT16, FP8_E4M3, FP8_E5M2, FXP4, FXP8,
              BF16, FP16, FP32)
}


def format_by_name(name: str) -> FormatSpec:
    return FORMATS[name]


def storage_bits(spec: FormatSpec) -> int:
    return spec.bits


def simd_lanes(spec: FormatSpec) -> int:
    """How many operands of this format fit one 16-bit XR-NPE SIMD lane."""
    return max(1, 16 // spec.bits)


def nar_code(spec: FormatSpec) -> int:
    if spec.kind == "posit":
        return 1 << (spec.bits - 1)
    if spec.kind == "minifloat" and spec.has_nan:
        return (1 << (spec.bits - 1)) - 1  # positive all-ones
    return 0


# ---------------------------------------------------------------------------
# Exact scalar decoders (numpy, run once per spec to build tables)
# ---------------------------------------------------------------------------

def _posit_value(code: int, n: int, es: int) -> float:
    mask = (1 << n) - 1
    code &= mask
    if code == 0:
        return 0.0
    if code == 1 << (n - 1):
        return float("nan")  # NaR
    sign = -1.0 if code >> (n - 1) else 1.0
    if sign < 0:
        code = (-code) & mask
    body = code & ((1 << (n - 1)) - 1)
    B = n - 1
    r0 = (body >> (B - 1)) & 1
    # run length of leading bits equal to r0
    m = 0
    for i in range(B - 1, -1, -1):
        if ((body >> i) & 1) == r0:
            m += 1
        else:
            break
    k = (m - 1) if r0 else -m
    consumed = min(m + 1, B)  # regime + terminating bit
    rem = B - consumed
    eb = min(es, rem)
    e = ((body >> (rem - eb)) & ((1 << eb) - 1)) << (es - eb) if eb else 0
    fbits = rem - eb
    frac = body & ((1 << fbits) - 1) if fbits else 0
    scale = k * (1 << es) + e
    return sign * (1.0 + frac / (1 << fbits if fbits else 1)) * (2.0 ** scale)


def _minifloat_value(code: int, ebits: int, mbits: int, has_nan: bool) -> float:
    bias = (1 << (ebits - 1)) - 1
    sign = -1.0 if (code >> (ebits + mbits)) & 1 else 1.0
    e = (code >> mbits) & ((1 << ebits) - 1)
    m = code & ((1 << mbits) - 1)
    if has_nan and e == (1 << ebits) - 1 and m == (1 << mbits) - 1:
        return float("nan")
    if e == 0:
        return sign * (m / (1 << mbits)) * (2.0 ** (1 - bias))
    return sign * (1.0 + m / (1 << mbits)) * (2.0 ** (e - bias))


def _fixed_value(code: int, bits: int, frac_bits: int) -> float:
    if code >= 1 << (bits - 1):
        code -= 1 << bits
    return code / (1 << frac_bits)


@functools.lru_cache(maxsize=None)
def code_values(spec: FormatSpec) -> np.ndarray:
    """float32 value of every raw code, indexed by code. NaN marks NaR."""
    if spec.kind == "native":
        raise ValueError("native formats have no code table")
    vals = np.empty(spec.ncodes, np.float64)
    for c in range(spec.ncodes):
        if spec.kind == "posit":
            vals[c] = _posit_value(c, spec.bits, spec.es)
        elif spec.kind == "minifloat":
            vals[c] = _minifloat_value(c, spec.ebits, spec.mbits, spec.has_nan)
        elif spec.kind == "fixed":
            vals[c] = _fixed_value(c, spec.bits, spec.frac_bits)
        else:  # pragma: no cover
            raise ValueError(spec.kind)
    return vals.astype(np.float32)


@functools.lru_cache(maxsize=None)
def _encode_tables(spec: FormatSpec):
    """(sorted_values f64, sorted_codes i32, boundaries f64) for encode.

    Sorted values are strictly increasing finite values (NaR dropped,
    -0/+0 deduplicated keeping the +0 code).  Boundary semantics follow
    the posit standard (softposit-compatible): the rounding boundary
    between two adjacent posits is the value of the *midpoint bit
    pattern* -- the (n+1)-bit posit ``(c << 1) | 1`` -- which equals the
    arithmetic midpoint within a regime but the geometric one across
    regime changes.  For minifloats IEEE RNE boundaries *are* arithmetic
    midpoints.  Ties resolve to the even (LSB=0) code.
    """
    vals = code_values(spec).astype(np.float64)
    codes = np.arange(spec.ncodes, dtype=np.int32)
    finite = np.isfinite(vals)
    vals, codes = vals[finite], codes[finite]
    order = np.argsort(vals, kind="stable")
    vals, codes = vals[order], codes[order]
    # dedup equal values (e.g. +-0): keep first occurrence, prefer code 0 for 0
    keep = np.ones(len(vals), bool)
    keep[1:] = vals[1:] != vals[:-1]
    zmask = vals == 0.0
    if zmask.any():
        codes[np.argmax(zmask)] = 0
    vals, codes = vals[keep], codes[keep]
    if spec.kind == "posit":
        n, es = spec.bits, spec.es
        # signed interpretation of each code, ascending with value
        signed = np.where(codes >= (1 << (n - 1)), codes - (1 << n),
                          codes).astype(np.int64)
        mids = (signed[:-1] << 1) + 1          # (n+1)-bit midpoint patterns
        bnds = np.array([_posit_value(int(m) & ((1 << (n + 1)) - 1),
                                      n + 1, es) for m in mids])
    else:
        bnds = (vals[:-1] + vals[1:]) / 2.0
    return vals, codes, bnds


# ---------------------------------------------------------------------------
# JAX encode / decode
# ---------------------------------------------------------------------------

def decode(spec: FormatSpec, codes: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Raw codes -> float values (NaR -> NaN)."""
    if spec.kind == "native":
        return codes.astype(dtype)
    table = jnp.asarray(code_values(spec))
    return table[codes.astype(jnp.int32) & (spec.ncodes - 1)].astype(dtype)


def encode(spec: FormatSpec, x: jax.Array) -> jax.Array:
    """float -> nearest raw code (int32). RNE-on-code; NaN -> NaR; saturating."""
    if spec.kind == "native":
        return x.astype(spec.dtype)
    svals, scodes, bnds = _encode_tables(spec)
    svals_j = jnp.asarray(svals)
    scodes_j = jnp.asarray(scodes)
    bnds_j = jnp.asarray(bnds)
    xf = x.astype(jnp.float64) if jax.config.x64_enabled else x.astype(jnp.float32)
    bnds_c = bnds_j if jax.config.x64_enabled else bnds_j.astype(jnp.float32)
    idx = jnp.searchsorted(bnds_c, xf, side="right").astype(jnp.int32)
    # tie: x exactly on boundary idx-1 -> lands on upper; move down if the
    # lower code is even (RNE on final code bit, per posit standard).
    lower = jnp.clip(idx - 1, 0, len(svals) - 1)
    on_tie = (idx > 0) & (xf == bnds_c[lower])
    lower_even = (scodes_j[lower] & 1) == 0
    idx = jnp.where(on_tie & lower_even, lower, idx)
    out = scodes_j[idx]
    if spec.kind == "posit":
        # posits never round a nonzero value to zero: clamp to +-minpos
        nonzero = (x != 0) & (out == 0)
        minpos_code = jnp.int32(1)
        maxneg_code = jnp.int32(spec.ncodes - 1)
        out = jnp.where(nonzero & (x > 0), minpos_code, out)
        out = jnp.where(nonzero & (x < 0), maxneg_code, out)
    nan_in = jnp.isnan(x)
    out = jnp.where(nan_in, jnp.int32(nar_code(spec)), out)
    return out


def quantize(spec: FormatSpec, x: jax.Array) -> jax.Array:
    """Round-trip x through the format's value grid (same dtype out)."""
    if spec.kind == "native":
        return x.astype(spec.dtype).astype(x.dtype)
    return decode(spec, encode(spec, x), dtype=x.dtype)


def bits_per_value(spec: FormatSpec) -> float:
    return float(spec.bits)


# ---------------------------------------------------------------------------
# Algorithmic (branch-free) decoders -- the in-kernel RMMEC datapath
# ---------------------------------------------------------------------------

def _clz_fixed(x: jax.Array, width: int) -> jax.Array:
    """Count leading zeros of ``x`` seen as a ``width``-bit integer."""
    return jnp.clip(jax.lax.clz(x.astype(jnp.int32)) - (32 - width), 0, width)


def decode_posit_bits(codes: jax.Array, n: int, es: int,
                      dtype=jnp.float32) -> jax.Array:
    """Vectorized posit decode with integer ops only (no table gather).

    Safe inside Pallas kernel bodies.  NaR decodes to 0 -- the hardware
    exception path of the paper's input-processing stage feeds zero to the
    accumulator, and weights produced by ``encode`` never contain NaR.
    """
    c = codes.astype(jnp.int32) & ((1 << n) - 1)
    B = n - 1
    neg = (c >> B) & 1
    is_zero = c == 0
    is_nar = c == (1 << B)
    mag = jnp.where(neg == 1, (1 << n) - c, c)
    body = mag & ((1 << B) - 1)
    r0 = (body >> (B - 1)) & 1
    t = jnp.where(r0 == 1, ~body, body) & ((1 << B) - 1)
    m = _clz_fixed(t, B)
    k = jnp.where(r0 == 1, m - 1, -m)
    consumed = jnp.minimum(m + 1, B)
    rem = B - consumed
    eb = jnp.minimum(es, rem)
    e = jnp.where(
        eb > 0,
        ((body >> jnp.maximum(rem - eb, 0)) & ((1 << es) - 1)) << (es - eb),
        0,
    ) if es > 0 else jnp.zeros_like(body)
    fbits = rem - eb
    frac = body & ((1 << jnp.maximum(fbits, 0)) - 1)
    scale = k * (1 << es) + e
    mant = 1.0 + jnp.ldexp(frac.astype(dtype), -fbits)
    val = jnp.ldexp(mant, scale)
    val = jnp.where(neg == 1, -val, val)
    return jnp.where(is_zero | is_nar, jnp.zeros_like(val), val)


def decode_minifloat_bits(codes: jax.Array, ebits: int, mbits: int,
                          dtype=jnp.float32, has_nan: bool = False) -> jax.Array:
    """Vectorized minifloat decode (subnormal-aware), kernel-safe.

    NaN codes decode to 0 -- the hardware exception path feeds zero to the
    accumulator (weights produced by ``encode`` never contain NaN codes).
    """
    n = 1 + ebits + mbits
    c = codes.astype(jnp.int32) & ((1 << n) - 1)
    bias = (1 << (ebits - 1)) - 1
    sign = jnp.where((c >> (ebits + mbits)) & 1, -1.0, 1.0).astype(dtype)
    e = (c >> mbits) & ((1 << ebits) - 1)
    m = (c & ((1 << mbits) - 1)).astype(dtype)
    sub = e == 0
    mant = jnp.where(sub, m / (1 << mbits), 1.0 + m / (1 << mbits))
    scale = jnp.where(sub, 1 - bias, e - bias)
    val = sign * jnp.ldexp(mant.astype(dtype), scale)
    if has_nan:
        is_nan = (e == (1 << ebits) - 1) & ((c & ((1 << mbits) - 1)) == (1 << mbits) - 1)
        val = jnp.where(is_nan, jnp.zeros_like(val), val)
    return val


def encode_posit_bits(x: jax.Array, n: int, es: int) -> jax.Array:
    """Branch-free posit encode, exact RNE (validated against the table
    encoder on every code + random sweeps).  No table gathers / wide
    broadcasts -- safe for giant tensors (QAT, 8-bit Adam) and kernels.

    Bit algebra (int32-safe): build regime|exponent|13-bit-mantissa in one
    integer, round once at the final width with guard/sticky (sticky
    carries the truncated low 10 mantissa bits).  Rounding carries
    propagate into the regime, which is exactly posit RNE; saturation
    clamps to +-maxpos and nonzero underflow to +-minpos (posits never
    round to zero or NaR).
    """
    B = n - 1
    xf = x.astype(jnp.float32)
    neg = xf < 0
    a = jnp.abs(xf)
    is_zero = a == 0
    is_nan = jnp.isnan(xf)
    m, E = jnp.frexp(jnp.where(is_zero | is_nan, 1.0, a))  # a = m*2^E
    scale = E - 1                                          # a = (2m)*2^scale
    maxscale = (n - 2) << es
    lo_clamp = scale < -maxscale
    hi_clamp = scale > maxscale
    scale = jnp.clip(scale, -maxscale, maxscale)
    k = scale >> es
    e = scale - (k << es)
    R = jnp.where(k >= 0, k + 2, 1 - k)
    pattern = jnp.where(k >= 0,
                        ((jnp.left_shift(1, jnp.clip(k + 1, 0, 30)) - 1) << 1),
                        1)
    m23 = jnp.round((2.0 * m - 1.0) * (1 << 23)).astype(jnp.int32)
    m13 = m23 >> 10
    st0 = (m23 & 1023) != 0
    V = (pattern << (es + 13)) | (e << 13) | m13
    drop = R + es + 13 - B                                # always >= 1
    keep = jnp.right_shift(V, drop)
    guard = jnp.right_shift(V, drop - 1) & 1
    low_mask = jnp.left_shift(1, jnp.clip(drop - 1, 0, 30)) - 1
    sticky = ((V & low_mask) != 0) | st0
    up = guard & (sticky | (keep & 1)).astype(jnp.int32)
    body = keep + up
    body = jnp.clip(body, 1, (1 << B) - 1)
    body = jnp.where(lo_clamp, 1, body)
    body = jnp.where(hi_clamp, (1 << B) - 1, body)
    code = jnp.where(neg, ((1 << n) - body) & ((1 << n) - 1), body)
    code = jnp.where(is_zero, 0, code)
    code = jnp.where(is_nan, 1 << B, code)
    return code.astype(jnp.int32)


def encode_minifloat_bits(x: jax.Array, ebits: int, mbits: int,
                          has_nan: bool = False) -> jax.Array:
    """Branch-free minifloat encode with subnormals + RNE + saturation."""
    xf = x.astype(jnp.float32)
    neg = xf < 0
    a = jnp.abs(xf)
    is_nan = jnp.isnan(xf)
    bias = (1 << (ebits - 1)) - 1
    emax = (1 << ebits) - 1
    # largest finite magnitude
    top_m = (1 << mbits) - (2 if has_nan else 1)
    max_fin = (1.0 + top_m / (1 << mbits)) * (2.0 ** (emax - bias))
    a = jnp.minimum(a, max_fin)
    _, E0 = jnp.frexp(jnp.where(a == 0, 1.0, a))
    E = jnp.clip(E0 - 1, 1 - bias, emax - bias)            # unbiased exp
    q = jnp.round(jnp.ldexp(a, mbits - E)).astype(jnp.int32)  # RNE, exact
    # mantissa overflow from rounding: 1.111.. -> 10.00 (exponent bump)
    bump = q >= (1 << (mbits + 1))
    E = jnp.where(bump, E + 1, E)
    q = jnp.where(bump, 1 << mbits, q)
    over = E > emax - bias
    E = jnp.minimum(E, emax - bias)
    sub = q < (1 << mbits)                                 # subnormal
    e_field = jnp.where(sub, 0, E + bias)
    m_field = jnp.where(sub, q, q - (1 << mbits))
    m_field = jnp.where(over, top_m, m_field)
    e_field = jnp.where(over, emax, e_field)
    code = (neg.astype(jnp.int32) << (ebits + mbits)) | \
        (e_field << mbits) | m_field
    if has_nan:
        nan_code = ((1 << (ebits + mbits)) - 1)
        code = jnp.where(is_nan, nan_code, code)
    return code.astype(jnp.int32)


def encode_bits(spec: FormatSpec, x: jax.Array) -> jax.Array:
    """Algorithmic encode dispatch (no tables; giant-tensor safe)."""
    if spec.kind == "posit":
        return encode_posit_bits(x, spec.bits, spec.es)
    if spec.kind == "minifloat":
        return encode_minifloat_bits(x, spec.ebits, spec.mbits, spec.has_nan)
    if spec.kind == "fixed":
        q = jnp.clip(jnp.round(x.astype(jnp.float32) * (1 << spec.frac_bits)),
                     -(spec.ncodes // 2), spec.ncodes // 2 - 1)
        return (q.astype(jnp.int32)) & (spec.ncodes - 1)
    raise ValueError(f"no bit encoder for {spec.kind}")


def quantize_bits(spec: FormatSpec, x: jax.Array) -> jax.Array:
    """Algorithmic round-trip (value-identical to ``quantize``; used on
    hot paths -- QAT forward, 8-bit optimizer state, grad compression)."""
    return decode_bits(spec, encode_bits(spec, x), dtype=jnp.float32) \
        .astype(x.dtype)


def decode_bits(spec: FormatSpec, codes: jax.Array, dtype=jnp.float32):
    """Dispatch to the kernel-safe algorithmic decoder for ``spec``."""
    if spec.kind == "posit":
        return decode_posit_bits(codes, spec.bits, spec.es, dtype)
    if spec.kind == "minifloat":
        return decode_minifloat_bits(codes, spec.ebits, spec.mbits, dtype,
                                     spec.has_nan)
    if spec.kind == "fixed":
        c = codes.astype(jnp.int32) & (spec.ncodes - 1)
        c = jnp.where(c >= spec.ncodes // 2, c - spec.ncodes, c)
        return c.astype(dtype) / (1 << spec.frac_bits)
    raise ValueError(f"no bit decoder for {spec.kind}")
