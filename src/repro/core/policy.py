"""Per-layer precision policy -- the software form of the co-processor's
configuration registers.

The XR-NPE host writes, per layer, a ``prec_sel`` plus layer geometry into
the accelerator's configuration/status registers before launching the
morphable array.  Here the same information is a ``PrecisionPolicy``: an
ordered list of (glob pattern over parameter paths -> format name) with a
default, resolved once per parameter tree and consumed by (a) QAT
fake-quant, (b) the packed serving plane, (c) the dry-run memory model.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import formats as fmt
from .formats import FormatSpec

__all__ = ["PrecisionPolicy", "param_paths", "flatten_with_paths"]


def flatten_with_paths(tree, keep_packed: bool = False) \
        -> List[Tuple[str, jax.Array]]:
    """Flatten a pytree to (slash-path, leaf); dict keys / sequence indices
    become path segments.  PackedTensors flatten into words/scales/mask
    sub-leaves (so sharding + checkpoint rules see real arrays) -- unless
    ``keep_packed``, in which case the PackedTensor node itself is the
    leaf (used by consumers of the packed aux metadata; ONE traversal
    definition, so paths always agree)."""
    leaves = []

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{path}/{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{path}/{i}" if path else str(i))
        elif node is None:
            return
        elif hasattr(node, "words") and hasattr(node, "scales"):
            if keep_packed:
                leaves.append((path, node))
            else:
                rec({"words": node.words, "scales": node.scales,
                     "mask": node.mask}, path)
        elif dataclasses.is_dataclass(node) and not isinstance(node, type):
            rec({f.name: getattr(node, f.name)
                 for f in dataclasses.fields(node)}, path)
        else:
            leaves.append((path, node))

    rec(tree, "")
    return leaves


def param_paths(tree) -> List[str]:
    return [p for p, _ in flatten_with_paths(tree)]


@dataclasses.dataclass
class PrecisionPolicy:
    """Ordered pattern rules; first match wins; ``default`` otherwise.

    ``keep_fp32`` patterns (norms, biases, embeddings by default) always
    stay in fp32 -- mirroring the paper's "minimal layers in higher
    precision" for critical layers.

    ``group_size``: K-group (block-wise) scale granularity of the packed
    serving plane AND of QAT fake-quant (both planes must share one
    grid: QAT trains against the grouping it serves with).  ``None`` is
    per-output-channel (the ``group=K`` special case).
    """

    rules: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    default: str = "fp32"
    keep_fp32: Tuple[str, ...] = (
        "*norm*", "*bias*", "*scale*", "*alpha*", "*embed*", "*rope*",
        "*state*", "*decay*", "*router*", "*d_skip*", "*conv_w*", "*a_log*",
        "*lora*", "*mix_*", "*bonus*", "*dt_proj*",
    )
    group_size: Optional[int] = None

    def format_for(self, path: str) -> FormatSpec:
        for pat in self.keep_fp32:
            if fnmatch.fnmatch(path, pat):
                return fmt.FP32
        for pat, name in self.rules:
            if fnmatch.fnmatch(path, pat):
                return fmt.format_by_name(name)
        return fmt.format_by_name(self.default)

    def group_for(self, path: str) -> Optional[int]:
        """Scale-group size for one parameter (None = per-channel).
        Native-format (incl. keep_fp32) leaves never group."""
        if self.group_size is None:
            return None
        return None if self.format_for(path).kind == "native" \
            else self.group_size

    def resolve(self, params) -> Dict[str, FormatSpec]:
        return {p: self.format_for(p) for p, _ in flatten_with_paths(params)}

    # -- memory model ------------------------------------------------------
    def model_bytes(self, params) -> int:
        """Packed model size under this policy (the paper's 13.5->2.42 MB)."""
        total = 0
        for path, leaf in flatten_with_paths(params):
            spec = self.format_for(path)
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            if spec.kind == "native":
                total += n * jax.dtypes.canonicalize_dtype(spec.dtype).itemsize
            else:
                total += (n * spec.bits + 7) // 8
                if len(leaf.shape) >= 2:
                    # f32 scale per (K-group, out-channel) per slice;
                    # per-channel is the groups=1 case (same accounting,
                    # so group-vs-channel byte comparisons are fair)
                    g = self.group_for(path)
                    groups = -(-leaf.shape[-2] // g) if g else 1
                    total += (n // (leaf.shape[-2] * leaf.shape[-1])) \
                        * groups * leaf.shape[-1] * 4
                else:
                    total += 4  # per-tensor scale
        return total

    def average_bits(self, params) -> float:
        bits = 0
        n_tot = 0
        for path, leaf in flatten_with_paths(params):
            spec = self.format_for(path)
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            b = spec.bits if spec.kind != "native" else \
                jax.dtypes.canonicalize_dtype(spec.dtype).itemsize * 8
            bits += n * b
            n_tot += n
        return bits / max(n_tot, 1)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "rules": self.rules, "default": self.default,
            "keep_fp32": list(self.keep_fp32),
            "group_size": self.group_size,
        })

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPolicy":
        d = json.loads(s)
        return cls(rules=[tuple(r) for r in d["rules"]], default=d["default"],
                   keep_fp32=tuple(d["keep_fp32"]),
                   group_size=d.get("group_size"))

    # -- convenience constructors ------------------------------------------
    @classmethod
    def uniform(cls, name: str) -> "PrecisionPolicy":
        return cls(rules=[], default=name)

    @classmethod
    def paper_mixed(cls) -> "PrecisionPolicy":
        """The paper's headline MxP scheme: Posit-8 for sensitive projection
        layers, HFP4 elsewhere (first/last layers protected by keep_fp32)."""
        return cls(rules=[("*attn*", "posit8_0"), ("*out_proj*", "posit8_0"),
                          ("*head*", "posit16_1")],
                   default="fp4")
