"""QAT tree transform: fake-quantize parameter subtrees per policy.

Called per-layer *inside* the scan-over-layers body so only one layer's
quantized copy is ever live (at trillion-param scale a whole-tree
quantized copy would blow HBM peak; see DESIGN.md).
"""

from __future__ import annotations

from typing import Optional

from . import quant
from .policy import PrecisionPolicy

__all__ = ["quantize_tree"]


def quantize_tree(tree, policy: Optional[PrecisionPolicy], prefix: str = ""):
    """Fake-quantize every matrix leaf (ndim >= 2) per ``policy``.

    ``prefix`` lets per-layer subtrees resolve against full-tree patterns
    (e.g. prefix='layers' inside the scan body).
    """
    if policy is None:
        return tree

    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, f"{path}/{i}" if path else str(i))
                              for i, v in enumerate(node))
        if node is None:
            return None
        if getattr(node, "ndim", 0) < 2:
            return node
        spec = policy.format_for(path)
        if spec.kind == "native":
            return node
        return quant.fake_quant(spec, node, group_size=policy.group_for(path))

    return rec(tree, prefix)
