"""SIMD word packing -- the XR-NPE lane layout, widened to 32-bit words.

The paper packs 4x4-bit / 2x8-bit / 1x16-bit operands per 16-bit SIMD lane.
On TPU the natural storage word is uint32, so we pack 8x4b / 4x8b / 2x16b
codes per word, little-endian within the word.  Packed tensors are what hit
HBM: this is where the memory-bandwidth reduction (the paper's headline
energy win -- off-chip movement ~60% of system energy) physically comes
from in the JAX port.

Packing is along the *last* axis; the axis is padded to a whole number of
words with zeros (zero is a valid code for every supported format and
decodes to 0.0, so padding is harmless for GEMM tails).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import jax

from .formats import FormatSpec

__all__ = ["pack", "unpack", "packed_last_dim", "packed_nbytes", "lanes_per_word"]

WORD_BITS = 32


def lanes_per_word(bits: int) -> int:
    if WORD_BITS % bits:
        raise ValueError(f"{bits}-bit codes do not tile a {WORD_BITS}-bit word")
    return WORD_BITS // bits


def packed_last_dim(k: int, bits: int) -> int:
    per = lanes_per_word(bits)
    return (k + per - 1) // per


def pack(codes: jax.Array, bits: int) -> jax.Array:
    """int codes [..., K] -> uint32 words [..., ceil(K/per)]."""
    per = lanes_per_word(bits)
    k = codes.shape[-1]
    kp = packed_last_dim(k, bits) * per
    if kp != k:
        pad = [(0, 0)] * (codes.ndim - 1) + [(0, kp - k)]
        codes = jnp.pad(codes, pad)
    c = codes.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    c = c.reshape(codes.shape[:-1] + (kp // per, per))
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(bits)
    return jnp.bitwise_or.reduce(c << shifts, axis=-1)


def unpack(words: jax.Array, bits: int, k: int) -> jax.Array:
    """uint32 words [..., W] -> int32 codes [..., k]."""
    per = lanes_per_word(bits)
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(bits)
    c = (words[..., None] >> shifts) & jnp.uint32((1 << bits) - 1)
    c = c.reshape(words.shape[:-1] + (words.shape[-1] * per,))
    return c[..., :k].astype(jnp.int32)


def packed_nbytes(shape, bits: int) -> int:
    """Bytes of the packed representation of a tensor of ``shape``."""
    if not shape:
        return 4
    k = shape[-1]
    rest = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    return rest * packed_last_dim(k, bits) * 4
