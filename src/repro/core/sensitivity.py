"""Layer-adaptive precision assignment (paper eq. 1-2).

The paper scores each layer with a first-order-Taylor sensitivity

    s_{l,sc,k} = ( ||Q^MxP(w_l) - w_l|| - ||Q^MxP'_{sc,k}(w_l) - w_l|| )
                 * ||grad L_{w_l}|| / n_l                      (eq. 1)
    s_l        = max(s_{l,sc,8}, s_{l,sc,4})                   (eq. 2)

i.e. how much the quantization error *changes* when layer l is dropped from
the base mixed precision to an sc-bit candidate, weighted by the loss
gradient magnitude (the Taylor term) and normalized per element.  Layers
with low s_l tolerate aggressive low-bit formats; the top-sensitive layers
are kept in higher precision.  The evaluation is done offline, "before
inference itself", exactly as here: one calibration gradient suffices.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import formats as fmt
from . import quant
from .formats import FormatSpec
from .policy import PrecisionPolicy, flatten_with_paths

__all__ = ["layer_sensitivity", "assign_layer_adaptive", "sensitivity_report"]


def _quant_err(spec: FormatSpec, w: jax.Array) -> jax.Array:
    q = quant.fake_quant(spec, w)
    return jnp.linalg.norm((q - w).ravel())


def layer_sensitivity(
    params,
    grads,
    base: FormatSpec = fmt.POSIT16,
    candidates: Sequence[FormatSpec] = (fmt.POSIT8, fmt.FP4),
) -> Dict[str, float]:
    """s_l per parameter path (eq. 1-2). ``grads`` is one calibration
    gradient tree (same structure as params)."""
    p_leaves = flatten_with_paths(params)
    g_leaves = dict(flatten_with_paths(grads))
    scored: Dict[str, Any] = {}
    for path, w in p_leaves:
        if w.ndim < 2:  # norms/biases: never candidates, skip scoring
            continue
        g = g_leaves.get(path)
        if g is None:
            continue
        n_l = float(np.prod(w.shape))
        gnorm = jnp.linalg.norm(g.ravel())
        base_err = _quant_err(base, w)
        scores = []
        for cand in candidates:  # eq. 2: max over the sc in {8, 4} arms
            cand_err = _quant_err(cand, w)
            scores.append(jnp.abs(base_err - cand_err) * gnorm / n_l)
        scored[path] = jnp.max(jnp.stack(scores))
    # ONE batched device->host sync for every leaf's score -- float()
    # inside the loop blocked on a round trip per parameter
    return {path: float(v) for path, v in jax.device_get(scored).items()}


def assign_layer_adaptive(
    params,
    grads,
    target_avg_bits: float = 6.0,
    low: FormatSpec = fmt.FP4,
    mid: FormatSpec = fmt.POSIT8,
    high: FormatSpec = fmt.POSIT16,
    keep_fp32: Optional[Tuple[str, ...]] = None,
) -> PrecisionPolicy:
    """Greedy budgeted assignment: rank layers by s_l ascending; the least
    sensitive get ``low``, then ``mid``, keeping the most sensitive few in
    ``high``, until the weighted average hits ``target_avg_bits``.

    This reproduces the paper's hybrid layer-adaptive scheme (HFP4 +
    Posit-8 + Posit-16 mixture, e.g. the 2.42 MB UL-VIO model).
    """
    sens = layer_sensitivity(params, grads, base=high, candidates=(mid, low))
    sizes = {p: int(np.prod(w.shape))
             for p, w in flatten_with_paths(params) if p in sens}
    order = sorted(sens, key=lambda p: sens[p])  # least sensitive first
    total = sum(sizes.values())
    assign: Dict[str, str] = {p: high.name for p in order}

    def avg_bits() -> float:
        spec_bits = {low.name: low.bits, mid.name: mid.bits,
                     high.name: high.bits}
        return sum(sizes[p] * spec_bits[assign[p]] for p in order) / max(total, 1)

    # two passes: first drop to mid, then the least-sensitive of those to low
    for p in order:
        if avg_bits() <= target_avg_bits:
            break
        assign[p] = mid.name
    for p in order:
        if avg_bits() <= target_avg_bits:
            break
        assign[p] = low.name

    rules = [(p, name) for p, name in assign.items()]
    pol = PrecisionPolicy(rules=rules, default=high.name)
    if keep_fp32 is not None:
        pol.keep_fp32 = keep_fp32
    return pol


def sensitivity_report(params, grads, **kw) -> str:
    sens = layer_sensitivity(params, grads, **kw)
    lines = ["layer-sensitivity (eq.1-2), ascending:"]
    for p in sorted(sens, key=lambda p: sens[p]):
        lines.append(f"  {sens[p]:.3e}  {p}")
    return "\n".join(lines)
