"""XR-NPE engine facade: cycle-level-faithful *semantics* emulation.

This is the software twin of Fig. 3's datapath used by the benchmarks and
faithfulness tests: given packed operand words and a ``prec_sel`` mode, it
runs the four stages -- input processing (decode + exception handling),
multiplication (sign/exponent/mantissa), quire scale-accumulate, output
processing (rounding) -- and reports the *power-gating statistics* the
paper's dark-silicon argument rests on (fraction of MACs skipped because
an operand is zero).

The production path is ``kernels.rmmec_matmul``; this facade trades speed
for introspection.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import codec
from . import formats as fmt
from .formats import FormatSpec
from .packing import lanes_per_word, unpack

__all__ = ["NPEStats", "simd_mac", "simd_dot_packed", "PREC_SEL"]

# prec_sel register encoding (paper: mode signal selecting the datapath)
PREC_SEL = {
    0: fmt.FP4,       # 4x FP4 per 16-bit lane
    1: fmt.POSIT4,    # 4x Posit(4,1)
    2: fmt.POSIT8,    # 2x Posit(8,0)
    3: fmt.POSIT16,   # 1x Posit(16,1)
}


@dataclasses.dataclass
class NPEStats:
    """Observable engine counters (the paper's Table II drivers)."""
    macs_total: int
    macs_gated: int          # zero-operand power-gated multiplies
    lanes_per_word: int
    operand_bits: int
    packed_bytes: int        # HBM bytes for the operands
    dense_bytes: int         # fp32 equivalent

    @property
    def gating_fraction(self) -> float:
        return self.macs_gated / max(self.macs_total, 1)

    @property
    def ai_gain_vs_fp32(self) -> float:
        return self.dense_bytes / max(self.packed_bytes, 1)


def simd_mac(acc: jax.Array, a_codes: jax.Array, b_codes: jax.Array,
             spec: FormatSpec) -> Tuple[jax.Array, jax.Array]:
    """One SIMD MAC step: acc += decode(a) * decode(b), with zero-operand
    gating (zeros feed the accumulator unchanged, as in the paper).

    Returns (acc, gated_mask)."""
    a = codec.decode(spec, a_codes)
    b = codec.decode(spec, b_codes)
    gated = (a_codes == 0) | (b_codes == 0)
    prod = jnp.where(gated, 0.0, a * b)
    return acc + prod, gated


def simd_dot_packed(a_words: jax.Array, b_words: jax.Array, k: int,
                    prec_sel: int) -> Tuple[jax.Array, NPEStats]:
    """Dot product over packed operand streams at mode ``prec_sel``.

    a_words/b_words: (W,) uint32 packed streams holding ``k`` codes each.
    Returns (result f32 scalar, NPEStats)."""
    spec = PREC_SEL[prec_sel]
    a_codes = unpack(a_words, spec.bits, k)
    b_codes = unpack(b_words, spec.bits, k)
    acc = jnp.zeros((), jnp.float32)
    acc, gated = simd_mac(acc[None], a_codes, b_codes, spec)
    result = jnp.sum(acc)
    stats = NPEStats(
        macs_total=k,
        macs_gated=int(jnp.sum(gated)),
        lanes_per_word=lanes_per_word(spec.bits),
        operand_bits=spec.bits,
        packed_bytes=int(a_words.size + b_words.size) * 4,
        dense_bytes=2 * k * 4,
    )
    return result, stats
