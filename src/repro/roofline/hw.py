"""Target-hardware constants (TPU v5e; per system-prompt numbers)."""

from __future__ import annotations

import dataclasses

__all__ = ["HW", "TPU_V5E"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per chip (link injection bandwidth)
    hbm_bytes: float           # capacity per chip


TPU_V5E = HW(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)
