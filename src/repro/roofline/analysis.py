"""Three-term roofline from the compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * peak)        [cost_analysis]
  memory term     = HLO_bytes / (chips * hbm_bw)      [cost_analysis]
  collective term = wire_bytes / (chips * ici_bw)     [parsed from HLO]

Empirics on this JAX/XLA (verified in-session): ``compiled.cost_analysis()``
reports *per-device* flops/bytes for SPMD programs, so the division by
``chips`` is already done -- terms use the per-device numbers directly.
Collectives appear only in ``compiled.as_text()`` (post-partitioner), with
per-device shard shapes; we record both the spec's operand-sum and a
wire-model estimate (all-gather receives result-operand bytes; all-reduce
moves ~2x operand in a ring; reduce-scatter operand-result).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from .hw import HW, TPU_V5E

__all__ = ["collective_stats", "roofline_terms", "model_flops",
           "summarize_cell", "active_param_count", "total_param_count",
           "decode_kv_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<rshape>\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d+(?:\d+)?)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-device collective byte counts by op type, from compiled HLO."""
    out: Dict[str, float] = {k: 0.0 for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")}
    count = 0
    operand_sum = 0.0
    wire_sum = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        count += 1
        op = m.group("op")
        rbytes = _shape_bytes(m.group("rshape"))
        # operand shapes: inside the parens
        paren = line[m.end():]
        obytes = _shape_bytes(paren.split(")")[0])
        if obytes == 0:  # operand referenced by name only; fall back
            obytes = rbytes
        out[op] += obytes
        if op == "all-gather":
            wire_sum += max(rbytes - obytes, 0)
        elif op == "all-reduce":
            wire_sum += 2 * obytes
        elif op == "reduce-scatter":
            wire_sum += max(obytes - rbytes, 0)
        else:
            wire_sum += obytes
        operand_sum += obytes
    out["count"] = float(count)
    out["operand_bytes"] = operand_sum
    out["wire_bytes"] = wire_sum
    return out


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k + shared experts only)."""
    if not cfg.n_experts:
        return cfg.param_count()
    active = dataclasses.replace(
        cfg,
        n_experts=cfg.experts_per_tok,
        # shared experts / dense residual stay (they are always-on)
    )
    return active.param_count()


def total_param_count(cfg: ModelConfig) -> int:
    return cfg.param_count()


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs for the whole step (global, not per-device).

    train  : 6 * N_active * tokens   (fwd 2x + bwd 4x)
    prefill: 2 * N_active * tokens
    decode : 2 * N_active * batch    (one token per sequence)
             + attention KV reads are memory, not matmul flops
    """
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_act * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.seq_len * shape.global_batch
    return 2.0 * n_act * shape.global_batch


def roofline_terms(cost: Dict[str, float], colls: Dict[str, float],
                   chips: int, hw: HW = TPU_V5E,
                   per_device_cost: bool = True) -> Dict[str, float]:
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    if not per_device_cost:
        flops_dev /= chips
        bytes_dev /= chips
    t_compute = flops_dev / hw.peak_flops_bf16
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = colls.get("wire_bytes", 0.0) / hw.ici_bw
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "coll_wire_bytes_per_device": colls.get("wire_bytes", 0.0),
        "coll_operand_bytes_per_device": colls.get("operand_bytes", 0.0),
        "coll_count": colls.get("count", 0.0),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }


def min_traffic_bytes(cfg: ModelConfig, shape: ShapeConfig,
                      weight_bits: float = 4.5,
                      quantized_kv: bool = False) -> float:
    """Analytic minimum HBM traffic for the step (global bytes): the
    memory-side 'useful work' that no implementation can avoid.

    train  : params f32 read (fwd) + read (bwd) + grad write + opt m/v
             read+write (8-bit) + one activation-boundary pass per layer.
    prefill: packed weights once + activation stream per layer.
    decode : packed weights once + KV cache read (+write 1 token).
    """
    n = cfg.param_count()
    n_act = active_param_count(cfg)
    toks = shape.seq_len * shape.global_batch
    d = cfg.d_model
    if shape.kind == "train":
        w = n * 4 * 3 + n * 1 * 4            # fp32 fwd+bwd+gradw, 8bit m/v rw
        acts = cfg.n_layers * toks * d * 2 * 4   # bf16, ~4 boundary tensors
        return float(w + acts)
    wbytes = n_act * weight_bits / 8 if shape.kind == "decode" else \
        n_act * weight_bits / 8
    if shape.kind == "prefill":
        acts = cfg.n_layers * toks * d * 2 * 2
        return float(wbytes + acts)
    # decode: one token; KV read dominates
    kv_bits = 8 if quantized_kv else 16
    n_attn = cfg.n_attn_layers
    if cfg.family == "ssm":
        kv = shape.global_batch * cfg.n_layers * \
            (cfg.d_model // max(cfg.rwkv_head_dim, 1)) * \
            cfg.rwkv_head_dim ** 2 * 4 * 2
    else:
        kv = (2 * n_attn * shape.seq_len * cfg.n_kv_heads *
              cfg.resolved_head_dim * shape.global_batch * kv_bits / 8)
    return float(wbytes + kv)


def decode_kv_bytes(cfg: ModelConfig, batch: int, max_len: int, pos: int,
                    quantized: bool = False, kv_group=None,
                    length_aware: bool = True, blk: int = 128) -> float:
    """Modeled KV-cache HBM bytes moved by ONE decode step (all layers).

    bf16 baseline: the full (max_len) k+v buffers are read per step.
    quantized    : uint8 codes + bf16 scales in the unified
                   ``group_scales`` layout (Gs = Dh/kv_group columns);
                   with ``length_aware`` only the ceil((pos+1)/blk) live
                   KV blocks are touched -- independent of ``max_len``.
    This is the per-step model behind benchmarks/bench_decode.py; it uses
    the same attention-layer count as :func:`min_traffic_bytes`.
    """
    from ..models.attention import kv_scale_cols
    n_attn = cfg.n_attn_layers
    hd = cfg.resolved_head_dim
    rows = n_attn * batch * cfg.n_kv_heads        # per cached token
    if not quantized:
        return float(2 * rows * max_len * hd * 2)            # k+v bf16
    gs = kv_scale_cols(hd, kv_group)
    toks = -(-(pos + 1) // blk) * blk if length_aware else max_len
    return float(2 * rows * toks * (hd * 1 + gs * 2))        # codes+scales


def summarize_cell(cfg: ModelConfig, shape: ShapeConfig, terms: Dict,
                   chips: int, hw: HW = TPU_V5E,
                   weight_bits: float = 4.5,
                   quantized_kv: bool = False) -> Dict[str, float]:
    """Attach MODEL_FLOPS ratios + roofline fractions to the raw terms.

    Two fractions are reported:
      roofline_fraction_compute -- useful-FLOPs time at peak over the
        dominant term (the classic MFU-style number; apt for train).
      roofline_fraction -- ideal step time (max of useful-FLOPs time and
        analytic minimum-traffic time) over the dominant term: meaningful
        for memory-bound shapes (decode), where the floor is traffic, not
        FLOPs.  This is the score we hillclimb in §Perf.
    """
    mf = model_flops(cfg, shape)
    hlo_flops_global = terms["flops_per_device"] * chips
    useful_ratio = mf / hlo_flops_global if hlo_flops_global else 0.0
    t_useful_c = mf / (chips * hw.peak_flops_bf16)
    mt = min_traffic_bytes(cfg, shape, weight_bits, quantized_kv)
    t_useful_m = mt / (chips * hw.hbm_bw)
    t_ideal = max(t_useful_c, t_useful_m)
    bound = terms["bound_s"]
    out = dict(terms)
    out.update({
        "model_flops": mf,
        "useful_flops_ratio": useful_ratio,
        "min_traffic_bytes": mt,
        "t_ideal_s": t_ideal,
        "roofline_fraction_compute": t_useful_c / bound if bound else 0.0,
        "roofline_fraction": t_ideal / bound if bound else 0.0,
    })
    return out
