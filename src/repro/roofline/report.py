"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
artifacts/dryrun JSON records.

  PYTHONPATH=src python -m repro.roofline.report [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from ..configs import SHAPES, ARCH_IDS, all_cells

COLS = ["arch", "shape", "mesh", "policy", "dom", "t_comp", "t_mem",
        "t_coll", "frac", "useful", "temp_GiB", "args_GiB", "colls"]


def load_records(d: str, tag: str = ""):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        r = json.load(open(f))
        out[(r["arch"], r["shape"], tuple(r["mesh"]))] = r
    return out


def fmt_row(r) -> str:
    rf = r["roofline"]
    m = r["memory"]
    return ("| {arch} | {shape} | {mesh} | {policy} | {dom} | "
            "{tc:.4f} | {tm:.4f} | {tk:.4f} | {fr:.3f} | {uf:.2f} | "
            "{tmp:.1f} | {arg:.1f} | {nc:d} |").format(
        arch=r["arch"], shape=r["shape"],
        mesh="x".join(map(str, r["mesh"])), policy=r["policy"],
        dom=rf["dominant"], tc=rf["t_compute_s"], tm=rf["t_memory_s"],
        tk=rf["t_collective_s"], fr=rf["roofline_fraction"],
        uf=rf["useful_flops_ratio"],
        tmp=m["temp_bytes"] / 2**30, arg=m["argument_bytes"] / 2**30,
        nc=int(r["collectives"]["count"]))


HEADER = ("| arch | shape | mesh | policy | dominant | t_compute(s) | "
          "t_memory(s) | t_coll(s) | roofline_frac | useful_flops | "
          "temp GiB/dev | args GiB/dev | #coll |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="16x16",
                    help="16x16 (roofline, single-pod) | 2x16x16 "
                         "(multi-pod compile pass) | all")
    args = ap.parse_args()
    recs = load_records(args.dir, args.tag)
    if args.mesh != "all":
        want = tuple(int(x) for x in args.mesh.split("x"))
        recs = {k: v for k, v in recs.items() if k[2] == want}
    print(HEADER)
    done, skipped, missing = 0, 0, []
    for arch, sname, cfg, shp, runnable in all_cells():
        if not runnable:
            print(f"| {arch} | {sname} | - | - | SKIP (long_500k needs "
                  f"sub-quadratic attention; DESIGN.md §4) "
                  f"| | | | | | | | |")
            skipped += 1
            continue
        hit = [r for (a, s, m), r in recs.items()
               if a == arch and s == sname]
        if not hit:
            missing.append((arch, sname))
            continue
        for r in sorted(hit, key=lambda r: r["mesh"]):
            print(fmt_row(r))
            done += 1
    print(f"\ncells: {done} baselined, {skipped} documented skips, "
          f"{len(missing)} missing {missing if missing else ''}")


if __name__ == "__main__":
    main()
