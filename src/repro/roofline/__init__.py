from .hw import TPU_V5E  # noqa: F401
from .analysis import (collective_stats, roofline_terms, model_flops,
                       summarize_cell)  # noqa: F401
