"""Attention-free sequence mixers: Mamba (for Jamba) and RWKV-6 "Finch".

Both use chunked sequential scans: an outer ``lax.scan`` over sequence
chunks (optionally remat'ed -- the checkpoint boundary is the recurrent
state, so backward recomputes one chunk at a time) and an inner step scan.
Training state never materializes (B, S, inner, state); only (S/chunk)
boundary states persist, which is what makes the 500k-token cells
tractable -- these are the sub-quadratic architectures the long_500k
shape is assigned to.

Decode is a single O(1) state update -- no KV cache at all (the paper's
memory-bandwidth argument is strongest here: state + packed weights are
the whole working set).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from . import layers as L

__all__ = [
    "mamba_init", "mamba_apply", "mamba_decode", "mamba_state_init",
    "rwkv_init", "rwkv_time_mix", "rwkv_channel_mix", "rwkv_state_init",
    "rwkv_decode",
    "quantize_state", "dequantize_state", "requantize_state",
]


# ---------------------------------------------------------------------------
# Mamba (S6 selective SSM)
# ---------------------------------------------------------------------------

def _dt_rank(d_model: int) -> int:
    return max(1, math.ceil(d_model / 16))


def mamba_init(key, cfg):
    d, ds = cfg.d_model, cfg.mamba_d_state
    din = cfg.mamba_expand * d
    rank = _dt_rank(d)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * din),
        "conv_w": jax.random.normal(ks[1], (cfg.mamba_d_conv, din),
                                    jnp.float32) * 0.1,
        "conv_bias": jnp.zeros((din,), jnp.float32),
        "x_proj": L.dense_init(ks[2], din, rank + 2 * ds),
        "dt_proj": L.dense_init(ks[3], rank, din, bias=True),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (din, ds))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": L.dense_init(ks[4], din, d),
    }


def _causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv along seq. x: (B,S,din); w: (K,din)."""
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(k))
    return out + b.astype(x.dtype), xp[:, -(k - 1):]


def _mamba_scan(dt, bmat, cmat, xin, a, h0, chunk: int, remat: bool):
    """Selective scan. dt/xin: (B,S,din); bmat/cmat: (B,S,ds); a: (din,ds).

    Returns (y (B,S,din), h_final (B,din,ds))."""
    bsz, s, din = xin.shape
    ds = bmat.shape[-1]
    nchunks = max(s // chunk, 1)
    chunk = s // nchunks

    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs  # (B,din),(B,ds),(B,ds),(B,din)
        hbar = jnp.exp(dt_t[..., None] * a)                   # (B,din,ds)
        h = hbar * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    def chunk_body(h, xs):
        dt_c, b_c, c_c, x_c = xs  # (chunk, B, ...)
        h, y = jax.lax.scan(step, h, (dt_c, b_c, c_c, x_c))
        return h, y

    if remat:
        chunk_body = jax.checkpoint(chunk_body)

    def to_chunks(t):
        return t.swapaxes(0, 1).reshape(nchunks, chunk, *t.shape[:1],
                                        *t.shape[2:])

    xs = tuple(map(to_chunks, (dt, bmat, cmat, xin)))
    h, y = jax.lax.scan(chunk_body, h0, xs)
    y = y.reshape(s, bsz, din).swapaxes(0, 1)
    return y, h


def mamba_state_init(cfg, batch: int):
    din = cfg.mamba_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, din, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, din), jnp.float32),
    }


def _mamba_core(p, x, cfg, conv_state=None):
    din = cfg.mamba_expand * cfg.d_model
    rank = _dt_rank(cfg.d_model)
    xz = L.dense(p["in_proj"], x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", "seq", "ff")
    xin, new_conv = _causal_conv(xin, p["conv_w"], p["conv_bias"], conv_state)
    xin = jax.nn.silu(xin)
    dbl = L.dense(p["x_proj"], xin)
    dt, bmat, cmat = jnp.split(dbl, [rank, rank + cfg.mamba_d_state], -1)
    dt = jax.nn.softplus(L.dense(p["dt_proj"], dt)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    return xin, z, dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32), \
        a, new_conv


def mamba_apply(p, x, cfg, state=None):
    """x: (B,S,D) -> (out, new_state). Training / prefill path."""
    bsz = x.shape[0]
    if state is None:
        state = mamba_state_init(cfg, bsz)
    xin, z, dt, bmat, cmat, a, new_conv = _mamba_core(
        p, x, cfg, state["conv"])
    y, h = _mamba_scan(dt, bmat, cmat, xin.astype(jnp.float32), a,
                       state["h"], cfg.ssm_chunk, cfg.remat != "none")
    y = (y.astype(x.dtype) + p["d_skip"].astype(x.dtype) * xin)
    y = y * jax.nn.silu(z)
    return L.dense(p["out_proj"], y), {"h": h, "conv": new_conv}


def mamba_decode(p, x, cfg, state):
    """Single-token step: x (B,1,D)."""
    xin, z, dt, bmat, cmat, a, new_conv = _mamba_core(
        p, x, cfg, state["conv"])
    dt0 = dt[:, 0]
    hbar = jnp.exp(dt0[..., None] * a)
    h = hbar * state["h"] + (dt0 * xin[:, 0].astype(jnp.float32))[..., None] \
        * bmat[:, 0][:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None]
    y = (y.astype(x.dtype) + p["d_skip"].astype(x.dtype) * xin)
    y = y * jax.nn.silu(z)
    return L.dense(p["out_proj"], y), {"h": h, "conv": new_conv}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear attention
# ---------------------------------------------------------------------------

def rwkv_init(key, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    lora = 64
    ks = jax.random.split(key, 12)
    u = jax.random.normal(ks[0], (nh, hd), jnp.float32) * 0.1
    p = {
        # token-shift lerp coefficients
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_g": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": L.dense_init(ks[1], d, d),
        "wk": L.dense_init(ks[2], d, d),
        "wv": L.dense_init(ks[3], d, d),
        "wg": L.dense_init(ks[4], d, d),
        "wo": L.dense_init(ks[5], d, d),
        # data-dependent decay (the Finch contribution): w = exp(-exp(..))
        "decay_base": jnp.full((d,), -5.0, jnp.float32),
        "decay_lora_a": {"w": jax.random.normal(ks[6], (d, lora)) * 0.01},
        "decay_lora_b": {"w": jax.random.normal(ks[7], (lora, d)) * 0.01},
        "bonus_u": u,
        "ln_x": {"norm_scale": jnp.ones((d,), jnp.float32)},
        # channel mix
        "cm_mix_k": jnp.full((d,), 0.5, jnp.float32),
        "cm_mix_r": jnp.full((d,), 0.5, jnp.float32),
        "cm_key": L.dense_init(ks[8], d, cfg.d_ff),
        "cm_value": L.dense_init(ks[9], cfg.d_ff, d),
        "cm_receptance": L.dense_init(ks[10], d, d),
    }
    return p


def rwkv_state_init(cfg, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {
        "tm_state": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "tm_xprev": jnp.zeros((batch, d), jnp.float32),
        "cm_xprev": jnp.zeros((batch, d), jnp.float32),
    }


def _shift(x, xprev):
    """x: (B,S,D); xprev: (B,D) boundary token. Returns x_{t-1} stream."""
    return jnp.concatenate([xprev[:, None].astype(x.dtype), x[:, :-1]], 1)


def _wkv_scan(r, k, v, w, u, s0, chunk: int, remat: bool):
    """RWKV6 recurrence.  r/k/v/w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd).

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
          + k_t v_t^T          (per head; hd_k x hd_v state)."""
    bsz, s, nh, hd = r.shape
    nchunks = max(s // chunk, 1)
    chunk = s // nchunks

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       state + u[..., None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    def chunk_body(state, xs):
        state, y = jax.lax.scan(step, state, xs)
        return state, y

    if remat:
        chunk_body = jax.checkpoint(chunk_body)

    def to_chunks(t):  # (B,S,H,hd) -> (nchunks, chunk, B, H, hd)
        return t.swapaxes(0, 1).reshape(nchunks, chunk, bsz, nh, hd)

    xs = tuple(map(to_chunks, (r, k, v, w)))
    state, y = jax.lax.scan(chunk_body, s0, xs)
    y = y.reshape(s, bsz, nh, hd).swapaxes(0, 1)            # (B,S,H,hd)
    return y, state


def _tm_project(p, x, xprev, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    xp = _shift(x, xprev) if x.shape[1] > 1 else xprev[:, None].astype(x.dtype)

    def lerp(mix):
        return x + (xp - x) * mix.astype(x.dtype)

    b, s, _ = x.shape
    r = L.dense(p["wr"], lerp(p["mix_r"])).reshape(b, s, nh, hd)
    k = L.dense(p["wk"], lerp(p["mix_k"])).reshape(b, s, nh, hd)
    v = L.dense(p["wv"], lerp(p["mix_v"])).reshape(b, s, nh, hd)
    g = jax.nn.silu(L.dense(p["wg"], lerp(p["mix_g"])))
    # data-dependent decay (Finch): w_t = exp(-exp(base + lora(x_w)))
    xw = lerp(p["mix_w"])
    dd = L.dense(p["decay_lora_b"],
                 jnp.tanh(L.dense(p["decay_lora_a"], xw)))
    logw = p["decay_base"].astype(jnp.float32) + dd.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(b, s, nh, hd)
    return (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, g)


def rwkv_time_mix(p, x, cfg, state):
    """x: (B,S,D) -> (out, new_state)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    r, k, v, w, g = _tm_project(p, x, state["tm_xprev"], cfg)
    y, s_new = _wkv_scan(r, k, v, w, p["bonus_u"], state["tm_state"],
                         cfg.ssm_chunk, cfg.remat != "none")
    y = y.reshape(b, s, d).astype(x.dtype)
    y = L.rmsnorm(p["ln_x"], y)  # per-channel group norm stand-in
    out = L.dense(p["wo"], y * g)
    new_state = dict(state)
    new_state["tm_state"] = s_new
    new_state["tm_xprev"] = x[:, -1].astype(jnp.float32)
    return out, new_state


def rwkv_channel_mix(p, x, cfg, state):
    xp = _shift(x, state["cm_xprev"]) if x.shape[1] > 1 else \
        state["cm_xprev"][:, None].astype(x.dtype)
    xk = x + (xp - x) * p["cm_mix_k"].astype(x.dtype)
    xr = x + (xp - x) * p["cm_mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(L.dense(p["cm_key"], xk)))
    kk = shard(kk, "batch", "seq", "ff")
    out = jax.nn.sigmoid(L.dense(p["cm_receptance"], xr)) * \
        L.dense(p["cm_value"], kk)
    new_state = dict(state)
    new_state["cm_xprev"] = x[:, -1].astype(jnp.float32)
    return out, new_state


def rwkv_decode(p, x, cfg, state):
    """Single-token step for both mixes chained by the block in zoo."""
    return rwkv_time_mix(p, x, cfg, state)


# ---------------------------------------------------------------------------
# Quantized state (paged serving): posit8 codes + group scales per leaf
# ---------------------------------------------------------------------------
# The serving plane keeps recurrent state resident as posit8 codes plus
# bf16 group scales -- the same packing the paged KV pool uses -- so a
# request's state slab costs ~1 byte/element instead of 4.  Each f32
# leaf ``x`` becomes the pair ``x_codes`` / ``x_scale`` at the same
# dict level, quantized along the leaf's LAST dim (the contraction dim
# for both the Mamba h-state and the RWKV wkv matrix state).

def _state_items(node):
    """Stable iteration order so quantize/dequantize round-trip pytrees
    with identical structure regardless of insertion order."""
    return sorted(node.items())


def quantize_state(state, group=None):
    """Posit8-quantize every array leaf of a recurrent-state pytree.

    ``group`` follows :func:`attention.quantize_kv` semantics per leaf:
    leaves whose last dim the group does not divide degrade to one
    scale per row (never an error), so one pool-level knob applies
    uniformly across heterogeneous leaves."""
    from . import attention as A

    def rec(node):
        out = {}
        for key, val in _state_items(node):
            if isinstance(val, dict):
                out[key] = rec(val)
            else:
                codes, scale = A.quantize_kv(val, group)
                out[key + "_codes"] = codes
                out[key + "_scale"] = scale
        return out
    return rec(state)


def dequantize_state(state_q, dtype=jnp.float32):
    """Inverse of :func:`quantize_state` (decode to f32 by default --
    the recurrences accumulate in f32)."""
    from . import attention as A

    def rec(node):
        out = {}
        for key, val in _state_items(node):
            if isinstance(val, dict):
                out[key] = rec(val)
            elif key.endswith("_codes"):
                out[key[:-len("_codes")]] = A.dequantize_kv(
                    val, node[key[:-len("_codes")] + "_scale"], dtype)
        return out
    return rec(state_q)


def _leaf_group(codes, scale):
    """Recover the quantization group one leaf was packed with."""
    gs = int(scale.shape[-1])
    return None if gs == 1 else int(codes.shape[-1]) // gs


def requantize_state(state, state_q):
    """Quantize ``state`` back into the exact layout of ``state_q``.

    Group sizes are recovered PER LEAF from the old scales: a pool-level
    group that divides one leaf's last dim but not another's must
    degrade the same way on every round-trip, or decode-step state
    writes would change shape under ``lax.scan``."""
    from . import attention as A

    def rec(node, node_q):
        out = {}
        for key, val in _state_items(node):
            if isinstance(val, dict):
                out[key] = rec(val, node_q[key])
            else:
                grp = _leaf_group(node_q[key + "_codes"],
                                  node_q[key + "_scale"])
                codes, scale = A.quantize_kv(val, grp)
                out[key + "_codes"] = codes
                out[key + "_scale"] = scale
        return out
    return rec(state, state_q)
