"""Model zoo facade: build/init/apply by ModelConfig + precision planes.

Two precision planes (DESIGN.md §8):
  * QAT plane    -- ``quantize_params_fake`` fake-quantizes the fp32
    master tree per the PrecisionPolicy (forward sees low-bit values,
    grads flow via STE);
  * serving plane -- ``pack_params`` physically packs weight matrices to
    low-bit codes (PackedTensor leaves); matmuls then stream packed words,
    which is what the dry-run memory roofline measures.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import quant
from ..core import formats as fmt
from ..core.policy import PrecisionPolicy, flatten_with_paths
from ..kernels.ops import PackedTensor, pack_tensor
from . import attention as A
from . import transformer as T

__all__ = ["init_model", "apply_model", "decode_model", "init_cache",
           "init_state_cache", "loss_fn", "quantize_params_fake",
           "pack_params", "packed_bytes", "quantize_cache"]

init_model = T.lm_init
apply_model = T.lm_apply
decode_model = T.lm_decode
init_cache = T.init_cache
init_state_cache = T.init_state_cache
loss_fn = T.lm_loss


def quantize_params_fake(params, policy: PrecisionPolicy):
    """QAT plane: fake-quantize each matrix leaf per policy (STE-backed)."""
    flat = flatten_with_paths(params)
    specs = {p: policy.format_for(p) for p, _ in flat}

    def rec(node, path=""):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, f"{path}/{i}" if path else str(i))
                              for i, v in enumerate(node))
        if node is None:
            return None
        spec = specs[path]
        if spec.kind == "native" or node.ndim < 2:
            return node
        return quant.fake_quant(spec, node, group_size=policy.group_for(path))

    return rec(params)


_PACKABLE_SUFFIXES = ("/w", "experts/gate", "experts/up", "experts/down")


def pack_params(params, policy: PrecisionPolicy):
    """Serving plane: replace weight-matrix leaves with PackedTensors.

    Only true weight matrices are packed (``.../w`` dense weights and the
    stacked expert tensors); biases / norms / states stay dense even when
    their stacked form happens to be 2-D.  Stacked (layer/expert) weights
    pack per 2-D slice along the last axis, so ``lax.scan`` slices the
    packed leaves exactly like the dense ones.
    """

    def rec(node, path=""):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, f"{path}/{i}" if path else str(i))
                              for i, v in enumerate(node))
        if node is None:
            return None
        if not any(path.endswith(sfx) for sfx in _PACKABLE_SUFFIXES) \
                or node.ndim < 2:
            return node
        spec = policy.format_for(path)
        if spec.kind == "native":
            return node
        return pack_tensor(spec, node, group_size=policy.group_for(path))

    return rec(params)


def quantize_cache(cache, kv_group: Optional[int] = None,
                   quantize_state: bool = False):
    """One-shot posit8 quantization of a prefill cache.

    Walks the cache pytree and replaces every attention {k, v} pair
    (dense / moe: stacked (L, B, S, Kh, Dh); hybrid: per-group sub-dicts)
    with {k_codes, v_codes, k_scale, v_scale} in the unified
    ``quant.group_scales`` Dh-grouped layout.  SSM / RWKV / mamba states
    (no ``k``/``v`` keys) pass through untouched by default, so the
    engine can apply this uniformly across families; with
    ``quantize_state`` they quantize too (``ssm.quantize_state`` --
    the paged-STATE serving layout, where decode round-trips the state
    through posit8 every step).  Decode then continues writing the
    quantized KV layout incrementally (``attention._cache_write``).
    """
    from . import ssm as S

    def rec(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and not isinstance(node["k"], dict):
                kc, ks = A.quantize_kv(node["k"], kv_group)
                vc, vs = A.quantize_kv(node["v"], kv_group)
                return {"k_codes": kc, "k_scale": ks,
                        "v_codes": vc, "v_scale": vs}
            if quantize_state and ("h" in node or "tm_state" in node):
                return S.quantize_state(node, kv_group)
            return {k: rec(v) for k, v in node.items()}
        return node

    return rec(cache)


def packed_bytes(params, policy: PrecisionPolicy) -> int:
    return policy.model_bytes(params)


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for _, l in flatten_with_paths(params))
