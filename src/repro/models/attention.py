"""GQA attention: chunked (flash-style) training path, KV-cached decode.

Two training implementations, selected by ``cfg`` (a hillclimb lever --
see EXPERIMENTS.md §Perf):

  * ``scan``       -- online-softmax scan over KV chunks (compact HLO, but
                      causally-masked chunks still execute: ~2x FLOP waste
                      on the strictly-upper triangle);
  * ``triangular`` -- python-unrolled q-chunks, each attending only to its
                      causal KV prefix: the HLO contains exactly the useful
                      FLOPs (the XLA analogue of a flash kernel's block
                      skipping).

The KV cache supports optional Posit(8,0) quantization (beyond-paper
optimization aligned with its thesis: the decode memory roofline is KV +
weight bytes, and posit8 halves KV traffic vs bf16 at near-zero error).
Scales live in the unified ``quant.group_scales`` layout -- ``group``
codes along Dh share one po2 scale (``None`` = per-(token, head), the
group=Dh case) -- so the cache and weight planes grid identically
(``PrecisionPolicy.group_size`` threads both).  Quantized decode is
length-aware: a step at position ``pos`` reads/dequantizes only the
ceil((pos+1)/blk) live KV blocks, never the full ``max_len`` buffer,
either via the fused Pallas kernel (``kernels/flash_decode``,
``cfg.decode_impl == 'flash'``) or the pure-XLA ``fori_loop`` fallback
(``'blocked'``, the portable default -- the dry-run's host compile and
sharded caches go through XLA).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import codec as codec_mod
from ..core import formats as fmt
from ..core import quant
from ..parallel.sharding import shard
from . import layers as L

__all__ = ["attn_init", "attn_apply", "attn_decode", "attn_prefill_chunk",
           "quantize_kv", "dequantize_kv", "kv_scale_cols",
           "decode_quantized_blocks", "paged_decode_blocked",
           "paged_prefill_blocked"]


def attn_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d),
    }


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.rope_kind == "mrope":
        q = L.mrope(q, positions, cfg.rope_theta)
        k = L.mrope(k, positions, cfg.rope_theta)
    else:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _scores(q, k, softcap: float, f32: bool = True):
    """q: (B,Sq,Kh,G,Dh), k: (B,Skv,Kh,Dh) -> (B,Kh,G,Sq,Skv).

    ``f32=False`` keeps scores + softmax in bf16 (max-subtraction bounds
    the exp argument, so bf16 is numerically fine): halves the dominant
    HBM traffic of long-context attention (§Perf cell B, beyond-paper).
    """
    s = jnp.einsum(
        "bqkgd,btkd->bkgqt", q, k,
        preferred_element_type=jnp.float32 if f32 else jnp.bfloat16)
    s *= 1.0 / math.sqrt(q.shape[-1])
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _attend_block(q5, k, v, bias, f32: bool = True):
    """Full softmax attention on one block. q5: (B,Sq,Kh,G,Dh)."""
    s = _scores(q5, k, 0.0, f32) + bias.astype(
        jnp.float32 if f32 else jnp.bfloat16)      # (B,Kh,G,Sq,Skv)
    p = jax.nn.softmax(s, axis=-1).astype(q5.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


def attn_apply(p, x, cfg, positions=None, mode: str = "train",
               kv_mask=None):
    """Causal self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) -- the kv tensors feed cache initialization in
    prefill mode.

    ``kv_mask``: optional (B, S) bool, True = real token.  Keys at False
    slots are masked out of every query's softmax (ragged left-padded
    serving batches: pad tokens stop leaking into real ones).
    """
    b, s, d = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions, (3, b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    g = cfg.n_heads // cfg.n_kv_heads
    q5 = q.reshape(b, s, cfg.n_kv_heads, g, q.shape[-1])

    impl = getattr(cfg, "attn_impl", "triangular")
    f32 = getattr(cfg, "attn_scores_f32", True)
    c = min(cfg.seq_chunk, s)
    n_chunks = s // c if s % c == 0 else 1
    pad_bias = None
    if kv_mask is not None:
        # (B, 1, 1, 1, S): added onto the (1,1,1,Sq,Skv) causal bias
        pad_bias = jnp.where(kv_mask, 0.0, -1e30)[:, None, None, None, :]
    if n_chunks <= 1:
        bias = _causal_bias(s, s, 0)
        if pad_bias is not None:
            bias = bias + pad_bias
        out = _attend_block(q5, k, v, bias, f32)
    elif impl == "triangular":
        outs = []
        for i in range(n_chunks):
            qi = q5[:, i * c:(i + 1) * c]
            kv_len = (i + 1) * c
            bias = _causal_bias(c, kv_len, i * c)
            if pad_bias is not None:
                bias = bias + pad_bias[..., :kv_len]
            outs.append(_attend_block(qi, k[:, :kv_len], v[:, :kv_len],
                                      bias, f32))
        out = jnp.concatenate(outs, axis=1)
    else:  # online-softmax scan over kv chunks
        out = _flash_scan(q5, k, v, c, kv_mask)
    out = out.reshape(b, s, cfg.n_heads * q.shape[-1])
    out = shard(out, "batch", "seq", "heads")
    return L.dense(p["wo"], out), (k, v)


def _causal_bias(sq: int, skv: int, q_offset: int) -> jax.Array:
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    return jnp.where(kpos <= qpos, 0.0, -1e30)[None, None, None]


def _flash_scan(q5, k, v, c: int, kv_mask=None):
    """Online-softmax over KV chunks (lax.scan; numerically standard)."""
    b, s, kh, g, hd = q5.shape
    n = s // c
    k_c = k.reshape(b, n, c, kh, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n, c, kh, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(hd)
    qpos = jnp.arange(s)
    km_c = None
    if kv_mask is not None:
        km_c = kv_mask.reshape(b, n, c).transpose(1, 0, 2)   # (n, B, c)

    def body(carry, xs):
        acc, m, l = carry
        if km_c is None:
            kc, vc, idx = xs
            km = None
        else:
            kc, vc, idx, km = xs
        sc = jnp.einsum("bqkgd,btkd->bkgqt", q5, kc,
                        preferred_element_type=jnp.float32) * scale
        kpos = idx * c + jnp.arange(c)
        mask = (kpos[None, :] <= qpos[:, None])[None, None, None]  # (Sq, c)
        if km is not None:
            mask = mask & km[:, None, None, None, :]     # (B,1,1,Sq,c)
        sc = jnp.where(mask, sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(q5.dtype), vc)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, kh, g, s, hd), q5.dtype)
    m0 = jnp.full((b, kh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    xs = (k_c, v_c, jnp.arange(n)) if km_c is None else \
        (k_c, v_c, jnp.arange(n), km_c)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    out = acc / l[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)                  # (B,S,Kh,G,Dh)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def kv_scale_cols(head_dim: int, group_size: Optional[int]) -> int:
    """Scale columns per (token, head): Dh/group, or 1 when ``group_size``
    is None / does not divide Dh / is >= Dh (the group=Dh special case,
    matching the weight plane's per-channel degeneration)."""
    if not group_size or group_size >= head_dim or head_dim % group_size:
        return 1
    return head_dim // group_size


def quantize_kv(k: jax.Array, group_size: Optional[int] = None):
    """Posit8 quantization of a KV tensor (..., Dh) through the weight
    plane's ``quant.group_scales`` grid: ``group_size`` codes along Dh
    share one po2 (exponent-shift) scale.  ``None`` degenerates to one
    scale per (token, head) -- the seed layout, now as the group=Dh
    special case.  Returns (codes uint8 (..., Dh), scales bf16 (..., Gs))
    with Gs = ``kv_scale_cols(Dh, group_size)``."""
    dh = k.shape[-1]
    gs = kv_scale_cols(dh, group_size)
    g = None if gs == 1 else group_size
    # Dh plays K in the (..., K, N) grouping contract (trailing N=1 axis)
    s = quant.group_scales(fmt.POSIT8, k[..., None].astype(jnp.float32),
                           g, method="absmax_po2")[..., 0]   # (..., Gs)
    codes = codec_mod.encode(
        fmt.POSIT8,
        (k / jnp.repeat(s, dh // gs, axis=-1)).astype(jnp.float32))
    return codes.astype(jnp.uint8), s.astype(jnp.bfloat16)


def dequantize_kv(codes: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    """codes (..., Dh) + scales (..., Gs) -> (..., Dh) floats."""
    dh, gs = codes.shape[-1], scale.shape[-1]
    return (codec_mod.decode(fmt.POSIT8, codes.astype(jnp.int32))
            * jnp.repeat(scale.astype(jnp.float32), dh // gs,
                         axis=-1)).astype(dtype)


def _cache_group(layer_cache) -> Optional[int]:
    """Recover the Dh-group size a quantized layer cache was built with."""
    gs = layer_cache["k_scale"].shape[-1]
    dh = layer_cache["k_codes"].shape[-1]
    return None if gs == 1 else dh // gs


def _cache_write(layer_cache, k_new, v_new, pos):
    """Insert one token's k/v at position ``pos`` (B,1,Kh,Dh)."""
    if "k" in layer_cache:
        k = jax.lax.dynamic_update_slice(
            layer_cache["k"], k_new.astype(layer_cache["k"].dtype),
            (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            layer_cache["v"], v_new.astype(layer_cache["v"].dtype),
            (0, pos, 0, 0))
        return {"k": k, "v": v}
    group = _cache_group(layer_cache)
    kc, ks = quantize_kv(k_new, group)
    vc, vs = quantize_kv(v_new, group)
    out = dict(layer_cache)
    out["k_codes"] = jax.lax.dynamic_update_slice(
        layer_cache["k_codes"], kc, (0, pos, 0, 0))
    out["v_codes"] = jax.lax.dynamic_update_slice(
        layer_cache["v_codes"], vc, (0, pos, 0, 0))
    out["k_scale"] = jax.lax.dynamic_update_slice(
        layer_cache["k_scale"], ks, (0, pos, 0, 0))
    out["v_scale"] = jax.lax.dynamic_update_slice(
        layer_cache["v_scale"], vs, (0, pos, 0, 0))
    return out


def _online_softmax_block(qf, k, v, live, carry, softcap: float):
    """One online-softmax accumulation over a dequantized KV block: the
    XLA twin of ``kernels.flash_decode._online_softmax_step``.  The
    contiguous (:func:`decode_quantized_blocks`) and paged
    (:func:`paged_decode_blocked`) loops share this body -- their
    bitwise agreement is the invariant the paged-parity tests and
    ``ContinuousEngine`` token parity rest on.

    qf: (B, Kh, G, Dh) pre-scaled queries; k/v: (B, blk, Kh, Dh) f32;
    live: bool, broadcastable to (B, Kh, G, blk); carry: (acc, m, l).
    """
    acc, m, l = carry
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k,
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(live, s, -1e30)
    m_new = jnp.maximum(m, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l = l * alpha + p.sum(-1, keepdims=True)
    pv = jnp.einsum("bkgt,btkd->bkgd", p, v,
                    preferred_element_type=jnp.float32)
    return acc * alpha + pv, m_new, l


def decode_quantized_blocks(q4, layer_cache, pos, softcap: float = 0.0,
                            blk: Optional[int] = None,
                            pad=None) -> jax.Array:
    """Pure-XLA length-aware decode over a posit8 KV cache.

    Online-softmax ``fori_loop`` over KV blocks with a DYNAMIC trip count
    ceil((pos+1)/blk): each iteration dynamic-slices one (blk,) chunk of
    codes+scales out of HBM and dequantizes it; the dead tail of the
    ``max_len`` buffer is never read.  This is the portable analogue of
    ``kernels/flash_decode`` (same math, XLA-lowered -- works under the
    dry-run's host compile and on sharded caches).

    ``pad``: optional (B,) int32 left-pad widths of a ragged batch --
    cache slots below ``pad[b]`` hold pad-token KV and are masked out.

    q4: (B, Kh, G, Dh).  Returns (B, Kh, G, Dh) f32.
    """
    from ..kernels.flash_decode import default_kv_block
    b, kh, g, dh = q4.shape
    kc, ks = layer_cache["k_codes"], layer_cache["k_scale"]
    vc, vs = layer_cache["v_codes"], layer_cache["v_scale"]
    t = kc.shape[1]
    gs = ks.shape[-1]
    if blk is None:
        blk = default_kv_block(t)
    qf = q4.astype(jnp.float32) * (1.0 / math.sqrt(dh))

    def body(i, carry):
        start = i * blk
        kcb = jax.lax.dynamic_slice(kc, (0, start, 0, 0), (b, blk, kh, dh))
        ksb = jax.lax.dynamic_slice(ks, (0, start, 0, 0), (b, blk, kh, gs))
        vcb = jax.lax.dynamic_slice(vc, (0, start, 0, 0), (b, blk, kh, dh))
        vsb = jax.lax.dynamic_slice(vs, (0, start, 0, 0), (b, blk, kh, gs))
        kpos = start + jnp.arange(blk)
        live = kpos[None, None, None, :] <= pos
        if pad is not None:
            live = live & (kpos[None, None, None, :] >=
                           pad[:, None, None, None])
        return _online_softmax_block(
            qf, dequantize_kv(kcb, ksb, jnp.float32),
            dequantize_kv(vcb, vsb, jnp.float32), live, carry, softcap)

    acc0 = jnp.zeros((b, kh, g, dh), jnp.float32)
    m0 = jnp.full((b, kh, g, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kh, g, 1), jnp.float32)
    n_live = (pos + blk) // blk          # == ceil((pos + 1) / blk)
    acc, _, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    return acc / l


def paged_decode_blocked(q4, layer_cache, page_table, positions,
                         softcap: float = 0.0) -> jax.Array:
    """Pure-XLA paged decode: the portable analogue of
    ``kernels/flash_decode.paged_flash_decode_pallas``.

    The pool pages ARE the KV blocks: iteration ``t`` gathers each
    request's logical block ``t`` through its page-table row
    (``pool[page_table[:, t]]``) and runs the same online-softmax update
    as :func:`decode_quantized_blocks` -- identical math and block
    partition, so a contiguous and a paged decode of the same tokens
    agree bitwise when ``blk == page_size``.  The trip count is the MAX
    live-block count over the batch; a block past a shorter request's
    prefix is fully masked for that row and every update degenerates to
    an exact no-op (p = exp(-1e30 - m) underflows to 0, alpha = 1).

    q4         : (B, Kh, G, Dh) queries, one token per request.
    layer_cache: pool dict with k_codes/v_codes (P, page, Kh, Dh) and
                 k_scale/v_scale (P, page, Kh, Gs).
    page_table : (B, NP) int32, rows padded with a parking page id.
    positions  : (B,) int32 per-request positions.
    """
    b, kh, g, dh = q4.shape
    kc, ks = layer_cache["k_codes"], layer_cache["k_scale"]
    vc, vs = layer_cache["v_codes"], layer_cache["v_scale"]
    psize = kc.shape[1]
    qf = q4.astype(jnp.float32) * (1.0 / math.sqrt(dh))
    pos_col = positions[:, None, None, None]

    def body(t, carry):
        pg = jnp.take(page_table, t, axis=1)             # (B,)
        kpos = t * psize + jnp.arange(psize)
        live = kpos[None, None, None, :] <= pos_col
        return _online_softmax_block(
            qf, dequantize_kv(kc[pg], ks[pg], jnp.float32),
            dequantize_kv(vc[pg], vs[pg], jnp.float32), live, carry,
            softcap)

    acc0 = jnp.zeros((b, kh, g, dh), jnp.float32)
    m0 = jnp.full((b, kh, g, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kh, g, 1), jnp.float32)
    n_live = (jnp.max(positions) + psize) // psize
    acc, _, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    return acc / l


# ---------------------------------------------------------------------------
# Chunked paged prefill
# ---------------------------------------------------------------------------

def _online_softmax_qblock(qf, k, v, live, carry, softcap: float):
    """Online-softmax accumulation of a Q-query chunk over one KV block:
    the multi-query generalization of :func:`_online_softmax_block`
    (which stays untouched -- the decode parity invariants rest on its
    exact einsum shapes).

    qf: (B, Kh, G, Q, Dh) pre-scaled queries; k/v: (B, blk, Kh, Dh) f32;
    live: bool, broadcastable to (B, Kh, G, Q, blk);
    carry: (acc (B,Kh,G,Q,Dh), m (B,Kh,G,Q,1), l (B,Kh,G,Q,1)).
    """
    acc, m, l = carry
    s = jnp.einsum("bkgqd,btkd->bkgqt", qf, k,
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(live, s, -1e30)
    m_new = jnp.maximum(m, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l = l * alpha + p.sum(-1, keepdims=True)
    pv = jnp.einsum("bkgqt,btkd->bkgqd", p, v,
                    preferred_element_type=jnp.float32)
    return acc * alpha + pv, m_new, l


def paged_prefill_blocked(q5, layer_cache, page_table, start,
                          softcap: float = 0.0) -> jax.Array:
    """Pure-XLA PAGED chunk-prefill attention: a chunk of C queries at
    absolute positions ``start[b] .. start[b]+C-1`` attends causally
    through the request's page table -- its previously written pages
    plus its own (just-written) chunk pages.  The gather generalization
    of the prefill side of :func:`attn_apply`, mirroring
    :func:`paged_decode_blocked`: iteration ``t`` gathers each request's
    logical block ``t`` (``pool[page_table[:, t]]``), dequantizes it and
    runs one online-softmax update; blocks past the chunk's last
    position are exact no-ops.  Oracle:
    ``kernels.ref.paged_prefill_ref``.

    q5         : (B, C, Kh, G, Dh) chunk queries.
    layer_cache: pool dict with k_codes/v_codes (P, page, Kh, Dh) and
                 k_scale/v_scale (P, page, Kh, Gs).
    page_table : (B, NP) int32, rows padded with a parking page id.
    start      : (B,) int32 first absolute position of each chunk.

    Returns (B, C, Kh, G, Dh) f32.
    """
    b, c, kh, g, dh = q5.shape
    kc, ks = layer_cache["k_codes"], layer_cache["k_scale"]
    vc, vs = layer_cache["v_codes"], layer_cache["v_scale"]
    psize = kc.shape[1]
    qf = q5.astype(jnp.float32).transpose(0, 2, 3, 1, 4) \
        * (1.0 / math.sqrt(dh))                      # (B, Kh, G, C, Dh)
    qpos = start[:, None] + jnp.arange(c)            # (B, C)
    pos_col = qpos[:, None, None, :, None]           # (B, 1, 1, C, 1)

    def body(t, carry):
        pg = jnp.take(page_table, t, axis=1)         # (B,)
        kpos = t * psize + jnp.arange(psize)
        live = kpos[None, None, None, None, :] <= pos_col
        return _online_softmax_qblock(
            qf, dequantize_kv(kc[pg], ks[pg], jnp.float32),
            dequantize_kv(vc[pg], vs[pg], jnp.float32), live, carry,
            softcap)

    acc0 = jnp.zeros((b, kh, g, c, dh), jnp.float32)
    m0 = jnp.full((b, kh, g, c, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kh, g, c, 1), jnp.float32)
    n_live = (jnp.max(start) + c + psize - 1) // psize
    acc, _, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    return (acc / l).transpose(0, 3, 1, 2, 4)


def attn_prefill_chunk(p, x, cfg, positions, ctx):
    """Causal self-attention of ONE prefill chunk (chunked paged prefill).

    x: (B, C, D) chunk embeddings at absolute ``positions`` (B, C)
    (``start .. start+C-1``).  ``ctx`` is the per-layer context the
    chunk attends to in addition to itself, in one of two forms:

      * CARRY context ``{"k", "v"}``: (B, T, Kh, Dh) bf16 tensors
        holding the request's already-prefilled prefix (T == start).
        The chunk sees the same bf16 keys/values a monolithic prefill
        would, so chunked and monolithic prefill logits agree BITWISE
        (per-query full softmax does not depend on how queries are
        batched) -- this is the engine default and what the
        temperature-0 static-parity guarantee rests on.  Returns
        (out, {"k": chunk_k, "v": chunk_v}); the engine appends the
        chunk kv to the carry and quantizes it into pages.
      * PAGED context (the dict carries ``page_table``): the pool
        leaves + (B, NP) page table.  The chunk's kv is quantized and
        scattered into its pages FIRST (mirroring the decode write),
        then attention reads prefix + chunk back through the page table
        (:func:`paged_prefill_blocked`, or the fused kernel under
        ``decode_impl='flash'``).  Zero extra residency, but the
        context is posit8-dequantized, so logits differ from monolithic
        prefill at quantization error.  Returns (out, updated_ctx).
    """
    if "page_table" in ctx:
        return _attn_prefill_paged(p, x, cfg, positions, ctx)
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    g = cfg.n_heads // cfg.n_kv_heads
    hd = q.shape[-1]
    q5 = q.reshape(b, c, cfg.n_kv_heads, g, hd)
    t = ctx["k"].shape[1]
    kk = jnp.concatenate([ctx["k"].astype(k.dtype), k], axis=1) if t else k
    vv = jnp.concatenate([ctx["v"].astype(v.dtype), v], axis=1) if t else v
    bias = _causal_bias(c, t + c, t)
    if t:
        # the carry may be PREALLOCATED at the prompt's page-rounded
        # length (the engine dynamic-update-slices chunks in instead of
        # re-concatenating the whole prefix every chunk): only slots
        # below the chunk's start position hold live context, the rest
        # are zeros and must not attend.  Live slots add exactly 0.0,
        # so an exact-width carry (t == start) keeps bitwise parity
        # with monolithic prefill.
        kidx = jnp.arange(t + c)
        ctx_live = (kidx[None] < positions[:, :1]) | (kidx[None] >= t)
        bias = bias + jnp.where(ctx_live, 0.0,
                                -1e30)[:, None, None, None, :]
    out = _attend_block(q5, kk, vv, bias,
                        getattr(cfg, "attn_scores_f32", True))
    out = out.reshape(b, c, cfg.n_heads * hd)
    out = shard(out, "batch", "seq", "heads")
    return L.dense(p["wo"], out), {"k": k.astype(jnp.bfloat16),
                                   "v": v.astype(jnp.bfloat16)}


def _attn_prefill_paged(p, x, cfg, positions, ctx):
    """Paged chunk prefill: quantize + scatter the chunk's kv into its
    pages (page-aligned chunk slots -- the chunk/page contract of
    ``serve/paged_kv.py``), then attend to prefix + chunk through the
    page table."""
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    psize = ctx["k_codes"].shape[1]
    assert c % psize == 0, (c, psize)
    group = _cache_group(ctx)
    kc_new, ks_new = quantize_kv(k, group)
    vc_new, vs_new = quantize_kv(v, group)
    page_table = ctx["page_table"]
    start = positions[:, 0]
    nblk = c // psize
    blk_ids = start[:, None] // psize \
        + jnp.arange(nblk, dtype=jnp.int32)[None]    # (B, nblk)
    pgs = jnp.take_along_axis(page_table, blk_ids, axis=1).reshape(-1)
    out = dict(ctx)
    for key, src in (("k_codes", kc_new), ("v_codes", vc_new),
                     ("k_scale", ks_new), ("v_scale", vs_new)):
        s4 = src.reshape(b * nblk, psize, *src.shape[2:])
        out[key] = ctx[key].at[pgs].set(s4)
    g = cfg.n_heads // cfg.n_kv_heads
    hd = q.shape[-1]
    q5 = q.reshape(b, c, cfg.n_kv_heads, g, hd)
    if getattr(cfg, "decode_impl", "blocked") == "flash":
        from ..kernels.flash_decode import paged_flash_prefill_pallas
        from ..kernels.ops import should_interpret
        out5 = paged_flash_prefill_pallas(
            q5, out["k_codes"], out["k_scale"], out["v_codes"],
            out["v_scale"], page_table, start,
            softcap=cfg.attn_logit_softcap, interpret=should_interpret())
    else:
        out5 = paged_prefill_blocked(q5, out, page_table, start,
                                     cfg.attn_logit_softcap)
    o = out5.astype(x.dtype).reshape(b, c, cfg.n_heads * hd)
    return L.dense(p["wo"], o), out


def attn_decode(p, x, cfg, layer_cache, pos, pad=None):
    """One-token decode step. x: (B, 1, D); pos: scalar current position.

    Returns (out, updated_layer_cache).  A bf16 cache takes the dense
    full-buffer read (the baseline the benchmarks compare against); a
    posit8 cache takes the length-aware quantized path -- codes are
    dequantized per live block, on-chip, never materialized in HBM.
    A PAGED cache (the layer dict carries ``page_table``/``positions``)
    dispatches to :func:`_attn_decode_paged`: per-request positions, KV
    read/written through the page table, ``pos`` ignored.

    ``pad``: optional (B,) left-pad widths for ragged static batches --
    RoPE positions shift to ``pos - pad[b]`` and cache slots below
    ``pad[b]`` are masked, so mixed-length prompts decode like their
    unpadded selves.
    """
    if "page_table" in layer_cache:
        return _attn_decode_paged(p, x, cfg, layer_cache)
    b = x.shape[0]
    if pad is None:
        positions = jnp.full((b, 1), pos, jnp.int32)
    else:
        positions = (pos - pad).astype(jnp.int32)[:, None]
    if cfg.rope_kind == "mrope":
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    layer_cache = _cache_write(layer_cache, k_new, v_new, pos)
    # NOTE: no sharding constraint on the cache -- it arrives with its
    # input sharding (batch on data, head_dim on model) and forcing the
    # activation-rule layout all-gathered the full KV in f32 every layer
    # (measured: +6.5 GiB/layer/device on command-r decode; §Perf it1).
    g = cfg.n_heads // cfg.n_kv_heads
    hd = q.shape[-1]
    if "k" not in layer_cache:
        q4 = q.reshape(b, cfg.n_kv_heads, g, hd)
        if getattr(cfg, "decode_impl", "blocked") == "flash":
            from ..kernels.flash_decode import flash_decode_pallas
            from ..kernels.ops import should_interpret
            out4 = flash_decode_pallas(
                q4, layer_cache["k_codes"], layer_cache["k_scale"],
                layer_cache["v_codes"], layer_cache["v_scale"], pos,
                pad=pad, softcap=cfg.attn_logit_softcap,
                interpret=should_interpret())
        else:
            out4 = decode_quantized_blocks(q4, layer_cache, pos,
                                           cfg.attn_logit_softcap, pad=pad)
        out = out4.astype(x.dtype).reshape(b, 1, cfg.n_heads * hd)
        return L.dense(p["wo"], out), layer_cache
    k, v = layer_cache["k"], layer_cache["v"]
    q5 = q.reshape(b, 1, cfg.n_kv_heads, g, hd)
    s = _scores(q5, k, cfg.attn_logit_softcap)       # (B,Kh,G,1,T)
    tpos = jnp.arange(k.shape[1])
    live = tpos[None, None, None, None, :] <= pos
    if pad is not None:
        live = live & (tpos[None, None, None, None, :] >=
                       pad[:, None, None, None, None])
    s = jnp.where(live, s, -1e30)
    pw = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", pw, v)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return L.dense(p["wo"], out), layer_cache


def _attn_decode_paged(p, x, cfg, layer_cache):
    """Paged one-token decode: each request reads/writes posit8 KV pages
    through its page-table row at its OWN position (the layer cache
    carries ``page_table`` (B, NP) and ``positions`` (B,) alongside the
    pool pages; the engine broadcasts them over the layer-scan axis).

    The new token's quantized k/v land at pool slot
    ``(page_table[b, pos_b // page], pos_b % page)`` -- a batched scatter
    -- then attention runs over the live pages (fused Pallas kernel under
    ``decode_impl='flash'``, XLA gather fallback otherwise)."""
    b = x.shape[0]
    page_table = layer_cache["page_table"]
    positions = layer_cache["positions"]
    pos2 = positions[:, None]                   # (B, 1)
    if cfg.rope_kind == "mrope":
        # text continuation: t/h/w streams all advance with the 1-D
        # position, mirroring the contiguous decode path
        pos2 = jnp.broadcast_to(pos2, (3, b, 1))
    q, k_new, v_new = _qkv(p, x, cfg, pos2)
    psize = layer_cache["k_codes"].shape[1]
    group = _cache_group(layer_cache)
    kc_new, ks_new = quantize_kv(k_new, group)
    vc_new, vs_new = quantize_kv(v_new, group)
    pg = jnp.take_along_axis(page_table, (positions // psize)[:, None],
                             axis=1)[:, 0]
    row = positions % psize
    out = dict(layer_cache)
    out["k_codes"] = layer_cache["k_codes"].at[pg, row].set(kc_new[:, 0])
    out["v_codes"] = layer_cache["v_codes"].at[pg, row].set(vc_new[:, 0])
    out["k_scale"] = layer_cache["k_scale"].at[pg, row].set(ks_new[:, 0])
    out["v_scale"] = layer_cache["v_scale"].at[pg, row].set(vs_new[:, 0])
    g = cfg.n_heads // cfg.n_kv_heads
    hd = q.shape[-1]
    q4 = q.reshape(b, cfg.n_kv_heads, g, hd)
    if getattr(cfg, "decode_impl", "blocked") == "flash":
        from ..kernels.flash_decode import paged_flash_decode_pallas
        from ..kernels.ops import should_interpret
        out4 = paged_flash_decode_pallas(
            q4, out["k_codes"], out["k_scale"],
            out["v_codes"], out["v_scale"], page_table, positions,
            softcap=cfg.attn_logit_softcap, interpret=should_interpret())
    else:
        out4 = paged_decode_blocked(q4, out, page_table, positions,
                                    cfg.attn_logit_softcap)
    o = out4.astype(x.dtype).reshape(b, 1, cfg.n_heads * hd)
    return L.dense(p["wo"], o), out
