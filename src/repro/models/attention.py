"""GQA attention: chunked (flash-style) training path, KV-cached decode.

Two training implementations, selected by ``cfg`` (a hillclimb lever --
see EXPERIMENTS.md §Perf):

  * ``scan``       -- online-softmax scan over KV chunks (compact HLO, but
                      causally-masked chunks still execute: ~2x FLOP waste
                      on the strictly-upper triangle);
  * ``triangular`` -- python-unrolled q-chunks, each attending only to its
                      causal KV prefix: the HLO contains exactly the useful
                      FLOPs (the XLA analogue of a flash kernel's block
                      skipping).

The KV cache supports optional Posit(8,0) quantization (beyond-paper
optimization aligned with its thesis: the decode memory roofline is KV +
weight bytes, and posit8 halves KV traffic vs bf16 at near-zero error).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import codec as codec_mod
from ..core import formats as fmt
from ..parallel.sharding import shard
from . import layers as L

__all__ = ["attn_init", "attn_apply", "attn_decode", "init_kv_cache",
           "quantize_kv", "dequantize_kv"]


def attn_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d),
    }


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.rope_kind == "mrope":
        q = L.mrope(q, positions, cfg.rope_theta)
        k = L.mrope(k, positions, cfg.rope_theta)
    else:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _scores(q, k, softcap: float, f32: bool = True):
    """q: (B,Sq,Kh,G,Dh), k: (B,Skv,Kh,Dh) -> (B,Kh,G,Sq,Skv).

    ``f32=False`` keeps scores + softmax in bf16 (max-subtraction bounds
    the exp argument, so bf16 is numerically fine): halves the dominant
    HBM traffic of long-context attention (§Perf cell B, beyond-paper).
    """
    s = jnp.einsum(
        "bqkgd,btkd->bkgqt", q, k,
        preferred_element_type=jnp.float32 if f32 else jnp.bfloat16)
    s *= 1.0 / math.sqrt(q.shape[-1])
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _attend_block(q5, k, v, bias, f32: bool = True):
    """Full softmax attention on one block. q5: (B,Sq,Kh,G,Dh)."""
    s = _scores(q5, k, 0.0, f32) + bias.astype(
        jnp.float32 if f32 else jnp.bfloat16)      # (B,Kh,G,Sq,Skv)
    p = jax.nn.softmax(s, axis=-1).astype(q5.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


def attn_apply(p, x, cfg, positions=None, mode: str = "train"):
    """Causal self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) -- the kv tensors feed cache initialization in
    prefill mode.
    """
    b, s, d = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions, (3, b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    g = cfg.n_heads // cfg.n_kv_heads
    q5 = q.reshape(b, s, cfg.n_kv_heads, g, q.shape[-1])

    impl = getattr(cfg, "attn_impl", "triangular")
    f32 = getattr(cfg, "attn_scores_f32", True)
    c = min(cfg.seq_chunk, s)
    n_chunks = s // c if s % c == 0 else 1
    if n_chunks <= 1:
        bias = _causal_bias(s, s, 0)
        out = _attend_block(q5, k, v, bias, f32)
    elif impl == "triangular":
        outs = []
        for i in range(n_chunks):
            qi = q5[:, i * c:(i + 1) * c]
            kv_len = (i + 1) * c
            bias = _causal_bias(c, kv_len, i * c)
            outs.append(_attend_block(qi, k[:, :kv_len], v[:, :kv_len],
                                      bias, f32))
        out = jnp.concatenate(outs, axis=1)
    else:  # online-softmax scan over kv chunks
        out = _flash_scan(q5, k, v, c)
    out = out.reshape(b, s, cfg.n_heads * q.shape[-1])
    out = shard(out, "batch", "seq", "heads")
    return L.dense(p["wo"], out), (k, v)


def _causal_bias(sq: int, skv: int, q_offset: int) -> jax.Array:
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    return jnp.where(kpos <= qpos, 0.0, -1e30)[None, None, None]


def _flash_scan(q5, k, v, c: int):
    """Online-softmax over KV chunks (lax.scan; numerically standard)."""
    b, s, kh, g, hd = q5.shape
    n = s // c
    k_c = k.reshape(b, n, c, kh, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n, c, kh, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(hd)
    qpos = jnp.arange(s)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, idx = xs
        sc = jnp.einsum("bqkgd,btkd->bkgqt", q5, kc,
                        preferred_element_type=jnp.float32) * scale
        kpos = idx * c + jnp.arange(c)
        mask = kpos[None, :] <= qpos[:, None]            # (Sq, c)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(q5.dtype), vc)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, kh, g, s, hd), q5.dtype)
    m0 = jnp.full((b, kh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (k_c, v_c, jnp.arange(n)))
    out = acc / l[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)                  # (B,S,Kh,G,Dh)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, quantized: bool = False,
                  dtype=jnp.bfloat16, n_attn_layers: Optional[int] = None):
    """Stacked-over-layers KV cache pytree (scan-compatible)."""
    nl = n_attn_layers if n_attn_layers is not None else cfg.n_layers
    hd = cfg.resolved_head_dim
    shape = (nl, batch, max_len, cfg.n_kv_heads, hd)
    if quantized:
        return {
            "k_codes": jnp.zeros(shape, jnp.uint8),
            "v_codes": jnp.zeros(shape, jnp.uint8),
            "k_scale": jnp.ones(shape[:-1], jnp.bfloat16),
            "v_scale": jnp.ones(shape[:-1], jnp.bfloat16),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(k: jax.Array):
    """Per-(token, head) posit8 quantization of a KV tensor (..., Dh)."""
    s = jnp.max(jnp.abs(k), axis=-1) / 64.0 + 1e-8   # posit8 maxpos = 64
    s = jnp.exp2(jnp.ceil(jnp.log2(s)))
    codes = codec_mod.encode(fmt.POSIT8,
                             (k / s[..., None]).astype(jnp.float32))
    return codes.astype(jnp.uint8), s.astype(jnp.bfloat16)


def dequantize_kv(codes: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (codec_mod.decode(fmt.POSIT8, codes.astype(jnp.int32))
            * scale[..., None].astype(jnp.float32)).astype(dtype)


def _cache_read(layer_cache, dtype):
    if "k" in layer_cache:
        return layer_cache["k"], layer_cache["v"]
    k = dequantize_kv(layer_cache["k_codes"], layer_cache["k_scale"], dtype)
    v = dequantize_kv(layer_cache["v_codes"], layer_cache["v_scale"], dtype)
    return k, v


def _cache_write(layer_cache, k_new, v_new, pos):
    """Insert one token's k/v at position ``pos`` (B,1,Kh,Dh)."""
    if "k" in layer_cache:
        k = jax.lax.dynamic_update_slice(
            layer_cache["k"], k_new.astype(layer_cache["k"].dtype),
            (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            layer_cache["v"], v_new.astype(layer_cache["v"].dtype),
            (0, pos, 0, 0))
        return {"k": k, "v": v}
    kc, ks = quantize_kv(k_new)
    vc, vs = quantize_kv(v_new)
    out = dict(layer_cache)
    out["k_codes"] = jax.lax.dynamic_update_slice(
        layer_cache["k_codes"], kc, (0, pos, 0, 0))
    out["v_codes"] = jax.lax.dynamic_update_slice(
        layer_cache["v_codes"], vc, (0, pos, 0, 0))
    out["k_scale"] = jax.lax.dynamic_update_slice(
        layer_cache["k_scale"], ks, (0, pos, 0))
    out["v_scale"] = jax.lax.dynamic_update_slice(
        layer_cache["v_scale"], vs, (0, pos, 0))
    return out


def attn_decode(p, x, cfg, layer_cache, pos):
    """One-token decode step. x: (B, 1, D); pos: scalar current position.

    Returns (out, updated_layer_cache).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_kind == "mrope":
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    layer_cache = _cache_write(layer_cache, k_new, v_new, pos)
    k, v = _cache_read(layer_cache, x.dtype)
    # NOTE: no sharding constraint here -- the cache arrives with its
    # input sharding (batch on data, head_dim on model) and forcing the
    # activation-rule layout all-gathered the full KV in f32 every layer
    # (measured: +6.5 GiB/layer/device on command-r decode; §Perf it1).
    g = cfg.n_heads // cfg.n_kv_heads
    hd = q.shape[-1]
    q5 = q.reshape(b, 1, cfg.n_kv_heads, g, hd)
    s = _scores(q5, k, cfg.attn_logit_softcap)       # (B,Kh,G,1,T)
    tpos = jnp.arange(k.shape[1])
    s = jnp.where(tpos[None, None, None, None, :] <= pos, s, -1e30)
    pw = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", pw, v)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return L.dense(p["wo"], out), layer_cache
