"""Decoder blocks + scan-over-layers LM assembly for all four families.

Layer stacking conventions (compile-time hygiene on huge configs -- one
HLO block body regardless of depth):

  dense / moe / ssm : params['layers'] stacked over n_layers, lax.scan.
  hybrid (jamba)    : params['groups'] stacked over n_layers/attn_every;
                      each group body unrolls its attn_every sub-layers
                      (1 attention + k-1 mamba, FFN/MoE alternating by
                      global layer parity).

Caches thread through the same scans as xs/ys, so train / prefill /
decode share one code path per family.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.qat import quantize_tree
from ..parallel.sharding import shard
from . import attention as A
from . import layers as L
from . import moe as M
from . import ssm as S

__all__ = ["lm_init", "lm_apply", "lm_decode", "init_cache",
           "init_state_cache", "lm_loss"]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block_init(key, cfg, mixer: str, use_moe: bool):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(d)}
    if mixer == "attn":
        p["attn"] = A.attn_init(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = S.mamba_init(ks[0], cfg)
    elif mixer == "rwkv":
        p["rwkv"] = S.rwkv_init(ks[0], cfg)
    if mixer != "rwkv":  # rwkv carries its own channel mix
        p["ln2"] = L.rmsnorm_init(d)
        if use_moe:
            p["moe"] = M.moe_init(ks[1], cfg)
        else:
            p["ffn"] = L.ffn_init(ks[1], d, cfg.d_ff, cfg.ffn_kind,
                                  cfg.out_bias)
    else:
        p["ln2"] = L.rmsnorm_init(d)
    return p


def _block_apply(p, x, cfg, mixer: str, use_moe: bool, positions,
                 cache=None, pos=None, mode: str = "train",
                 pad=None, kv_mask=None):
    """Returns (x, new_cache, aux).  ``pad``/``kv_mask`` carry the ragged
    left-padded batch info to the attention mixer (decode / prefill);
    SSM mixers ignore them (ragged serving is attention-family only)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["ln1"], x)
    if mixer == "attn":
        if mode == "decode":
            h, cache = A.attn_decode(p["attn"], h, cfg, cache, pos, pad)
        elif mode == "prefill_chunk":
            # chunked paged prefill: one chunk attends to its per-layer
            # context (bf16 carry or the paged pool) plus itself; the
            # returned cache is the chunk's kv / the updated pool
            h, cache = A.attn_prefill_chunk(p["attn"], h, cfg, positions,
                                            cache)
        else:
            h, kv = A.attn_apply(p["attn"], h, cfg, positions, mode,
                                 kv_mask=kv_mask)
            if mode == "prefill":
                cache = {"k": kv[0].astype(jnp.bfloat16),
                         "v": kv[1].astype(jnp.bfloat16)}
    elif mixer == "mamba":
        # paged-state serving keeps the state as posit8 codes + scales:
        # round-trip through f32 for the step (the pool layout must
        # survive bitwise, so requantize against the incoming cache)
        state_q = cache if (cache is not None and "h_codes" in cache) \
            else None
        if state_q is not None:
            cache = S.dequantize_state(state_q)
        if mode == "decode":
            h, cache = S.mamba_decode(p["mamba"], h, cfg, cache)
        else:
            h, cache = S.mamba_apply(p["mamba"], h, cfg, cache)
        if state_q is not None:
            cache = S.requantize_state(cache, state_q)
    elif mixer == "rwkv":
        state_q = cache if (cache is not None and "tm_state_codes" in cache) \
            else None
        if state_q is not None:
            cache = S.dequantize_state(state_q)
        h, cache = (S.rwkv_time_mix(p["rwkv"], h, cfg, cache)
                    if cache is not None else
                    S.rwkv_time_mix(p["rwkv"], h, cfg,
                                    S.rwkv_state_init(cfg, x.shape[0])))
    x = x + h
    h2 = L.rmsnorm(p["ln2"], x)
    if mixer == "rwkv":
        h2, cache = S.rwkv_channel_mix(p["rwkv"], h2, cfg, cache)
        if state_q is not None:
            cache = S.requantize_state(cache, state_q)
    elif use_moe:
        h2, aux = M.moe_apply(p["moe"], h2, cfg)
    else:
        h2 = L.ffn(p["ffn"], h2, cfg.ffn_kind)
    x = x + h2
    return shard(x, "batch", "seq", "embed"), cache, aux


# ---------------------------------------------------------------------------
# Hybrid (jamba) group
# ---------------------------------------------------------------------------

def _group_layout(cfg):
    """Sub-layer layout inside one jamba group: mixer + moe flags."""
    k = cfg.attn_every
    attn_at = k // 2
    layout = []
    for i in range(k):
        mixer = "attn" if i == attn_at else "mamba"
        use_moe = cfg.n_experts > 0 and (i % cfg.moe_every == 1)
        layout.append((mixer, use_moe))
    return layout


def _group_init(key, cfg):
    layout = _group_layout(cfg)
    ks = jax.random.split(key, len(layout))
    return {f"b{i}": _block_init(ks[i], cfg, mixer, use_moe)
            for i, (mixer, use_moe) in enumerate(layout)}


def _group_apply(p, x, cfg, positions, cache=None, pos=None, mode="train",
                 pad=None, kv_mask=None, paged_meta=None):
    layout = _group_layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    # prefill materializes the group cache even from cache=None (it used
    # to be dropped, so hybrid decode-after-prefill had no state)
    new_cache = {} if (cache is not None or mode == "prefill") else None
    for i, (mixer, use_moe) in enumerate(layout):
        sub = cache.get(f"b{i}") if cache is not None else None
        # hybrid paged serving: the top-level page_table/positions meta
        # addresses only the ATTENTION sub-layer's pool leaves; the
        # mamba sub-layers carry fixed-size state slabs instead
        if paged_meta is not None and mixer == "attn" and sub is not None:
            sub = dict(sub, **paged_meta)
        x, c, a = _block_apply(p[f"b{i}"], x, cfg, mixer, use_moe,
                               positions, sub, pos, mode, pad, kv_mask)
        if paged_meta is not None and mixer == "attn" and c is not None:
            c = {k: v for k, v in c.items() if k not in paged_meta}
        if new_cache is not None:
            new_cache[f"b{i}"] = c
        aux = aux + a
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def _family_mixer(cfg) -> str:
    return {"dense": "attn", "moe": "attn", "ssm": "rwkv",
            "hybrid": "group"}[cfg.family]


def lm_init(key, cfg):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.frontend != "audio":
        p["embed"] = L.embed_init(ks[0], cfg.vocab, cfg.d_model)
    mixer = _family_mixer(cfg)
    if mixer == "group":
        n_groups = cfg.n_layers // cfg.attn_every
        gkeys = jax.random.split(ks[1], n_groups)
        p["groups"] = jax.vmap(lambda k: _group_init(k, cfg))(gkeys)
    else:
        use_moe = cfg.family == "moe"
        lkeys = jax.random.split(ks[1], cfg.n_layers)
        p["layers"] = jax.vmap(
            lambda k: _block_init(k, cfg, mixer, use_moe))(lkeys)
    p["final_norm"] = L.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend == "audio":
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab)
    return p


def _inputs_to_embeds(p, batch, cfg, dtype):
    """Resolve the modality frontend (stub per assignment: precomputed
    frame/patch embeddings arrive in the batch)."""
    if cfg.frontend == "audio":
        x = batch["frame_embeds"].astype(dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(p["embed"], tokens, dtype)
    if cfg.frontend == "vision":
        pe = batch["patch_embeds"].astype(dtype)
        np_ = pe.shape[1]
        x = jnp.concatenate([pe, x[:, np_:]], axis=1)
        positions = _mrope_positions(cfg, b, s, np_)
        return x, positions
    # ragged left-padded serving batches override the arange: position 0
    # sits at each request's first REAL token (engine supplies these)
    if "positions" in batch:
        return x, batch["positions"].astype(jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _mrope_positions(cfg, b, s, n_patches):
    """(3, B, S): patches get (t=0, h, w) grid ids; text continues 1-D."""
    side = max(int(n_patches ** 0.5), 1)
    idx = jnp.arange(s, dtype=jnp.int32)
    is_patch = idx < n_patches
    t = jnp.where(is_patch, 0, idx - n_patches + 1)
    h = jnp.where(is_patch, idx // side, idx - n_patches + 1)
    w = jnp.where(is_patch, idx % side, idx - n_patches + 1)
    pos3 = jnp.stack([t, h, w])                      # (3, S)
    return jnp.broadcast_to(pos3[:, None, :], (3, b, s))


def _scan_or_unroll(body, carry, xs, cfg):
    """lax.scan over stacked layers (compact HLO, production path) or a
    python unroll (``cfg.scan_layers=False``): identical semantics; the
    unrolled form exposes per-layer FLOPs to XLA's cost analysis and is
    what the dry-run's 1/2-layer probe compiles use."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


def _pop_paged_meta(cache):
    """Split a paged cache into (pool leaves, broadcast meta).

    The paged serving cache carries ONE ``page_table (B, NP)`` (and, for
    decode, ``positions (B,)``) at the TOP level of the cache dict, next
    to the L-stacked pool leaves.  The layer scan must not slice these
    (they have no layer axis), so callers pop them here, inject them
    into each per-layer cache inside the scan-body closure (a broadcast:
    every layer reads the same device-resident table), strip them from
    the per-layer results (or scan would stack them L x into ys), and
    re-attach them to the output cache so the pytree structure
    round-trips -- jit donation and the dry-run's ``out_shardings``
    both key on that structure."""
    if not (isinstance(cache, dict) and "page_table" in cache):
        return cache, None
    meta = {k: cache[k] for k in ("page_table", "positions") if k in cache}
    rest = {k: v for k, v in cache.items() if k not in meta}
    return rest, meta


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def lm_apply(p, batch, cfg, mode: str = "train", cache=None, policy=None):
    """Full-sequence forward.  Returns (logits, new_cache, aux).

    ``policy``: optional PrecisionPolicy for QAT -- layer weights are
    fake-quantized *inside* the scan body (one layer's copy live at a
    time), embed/head outside.
    """
    dtype = jnp.dtype(cfg.dtype)
    if policy is not None:
        p = dict(p)
        for k in ("embed", "lm_head", "final_norm"):
            if k in p:
                p[k] = quantize_tree(p[k], policy, k)
    x, positions = _inputs_to_embeds(p, batch, cfg, dtype)
    kv_mask = batch.get("kv_mask")      # ragged: (B, S) bool, True = real
    x = shard(x, "batch", "seq", "embed")
    mixer = _family_mixer(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    cache, paged_meta = _pop_paged_meta(cache)

    if mixer == "group":
        def body(carry, xs):
            x, aux = carry
            gp, gc = xs
            gp = quantize_tree(gp, policy, "groups")
            x, c, a = _group_apply(gp, x, cfg, positions, gc, mode=mode,
                                   kv_mask=kv_mask, paged_meta=paged_meta)
            return (x, aux + a), c
        body = _maybe_remat(body, cfg)
        (x, aux_total), new_cache = _scan_or_unroll(
            body, (x, aux_total), (p["groups"], cache), cfg)
    else:
        use_moe = cfg.family == "moe"

        def body(carry, xs):
            x, aux = carry
            lp, lc = xs
            if paged_meta is not None:
                lc = dict(lc, **paged_meta)
            lp = quantize_tree(lp, policy, "layers")
            x, c, a = _block_apply(lp, x, cfg, mixer, use_moe, positions,
                                   lc, mode=mode, kv_mask=kv_mask)
            if paged_meta is not None:
                c = {k: v for k, v in c.items() if k not in paged_meta}
            return (x, aux + a), c
        body = _maybe_remat(body, cfg)
        (x, aux_total), new_cache = _scan_or_unroll(
            body, (x, aux_total), (p["layers"], cache), cfg)
    if paged_meta is not None:
        new_cache = dict(new_cache, **paged_meta)

    x = L.rmsnorm(p["final_norm"], x)
    if "lm_head" in p:
        logits = L.dense(p["lm_head"], x)
    else:
        logits = L.embed_logits(p["embed"], x)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, new_cache, aux_total


def lm_decode(p, tokens, cfg, cache, pos, pad=None):
    """One decode step: tokens (B, 1) -> (logits (B,1,V), new_cache).

    ``pad``: optional (B,) left-pad widths of a ragged batch (threaded to
    the attention mixers).  A PAGED cache carries a single top-level
    ``page_table (B, NP)`` / ``positions (B,)`` pair next to the
    L-stacked pool leaves; both broadcast into every layer through the
    scan-body closure (never tiled L x) and ride back out on the
    returned cache so the pytree structure round-trips for donation /
    sharding.  Paged decode ignores ``pos`` entirely -- each request
    decodes at its own position."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        # autoregressive over audio codes: embed via lm_head weights^T
        from ..kernels.ops import PackedTensor, to_dense
        w = p["lm_head"]["w"]
        if isinstance(w, PackedTensor):
            w = to_dense(w, dtype)
        x = (w.astype(dtype).T)[tokens[..., 0]][:, None]
    else:
        x = L.embed(p["embed"], tokens, dtype)
    mixer = _family_mixer(cfg)
    cache, paged_meta = _pop_paged_meta(cache)

    if mixer == "group":
        def body(x, xs):
            gp, gc = xs
            x, c, _ = _group_apply(gp, x, cfg, None, gc, pos,
                                   mode="decode", pad=pad,
                                   paged_meta=paged_meta)
            return x, c
        x, new_cache = _scan_or_unroll(body, x, (p["groups"], cache), cfg)
    else:
        use_moe = cfg.family == "moe"

        def body(x, xs):
            lp, lc = xs
            if paged_meta is not None:
                lc = dict(lc, **paged_meta)
            x, c, _ = _block_apply(lp, x, cfg, mixer, use_moe, None,
                                   lc, pos, mode="decode", pad=pad)
            if paged_meta is not None:
                c = {k: v for k, v in c.items() if k not in paged_meta}
            return x, c
        x, new_cache = _scan_or_unroll(body, x, (p["layers"], cache), cfg)
    if paged_meta is not None:
        new_cache = dict(new_cache, **paged_meta)

    x = L.rmsnorm(p["final_norm"], x)
    if "lm_head" in p:
        logits = L.dense(p["lm_head"], x)
    else:
        logits = L.embed_logits(p["embed"], x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, quantized_kv: bool = False,
               kv_group: Optional[int] = None):
    """Stacked cache pytree matching the scan layout of ``cfg``.

    ``kv_group``: Dh-group size of the quantized KV scales (None =
    per-(token, head)); thread ``PrecisionPolicy.group_size`` here so
    the cache grids like the packed weight plane."""
    mixer = _family_mixer(cfg)
    if mixer == "rwkv":
        def one(_):
            return S.rwkv_state_init(cfg, batch)
        return jax.vmap(one)(jnp.arange(cfg.n_layers))
    if mixer == "group":
        layout = _group_layout(cfg)
        n_groups = cfg.n_layers // cfg.attn_every

        def one(_):
            g = {}
            for i, (m, _u) in enumerate(layout):
                if m == "attn":
                    g[f"b{i}"] = _one_kv(cfg, batch, max_len, quantized_kv,
                                         kv_group)
                else:
                    g[f"b{i}"] = S.mamba_state_init(cfg, batch)
            return g
        return jax.vmap(one)(jnp.arange(n_groups))
    # dense / moe: plain kv stacks
    def one(_):
        return _one_kv(cfg, batch, max_len, quantized_kv, kv_group)
    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def init_state_cache(cfg, batch: int):
    """Recurrent-state-only slice of :func:`init_cache`.

    The fixed-size per-request leaves a serving pool turns into state
    SLABS: the rwkv per-layer state stack, or the mamba sub-block
    states of a hybrid group (the attention sub-block pages through the
    KV pool instead).  Returns ``None`` for pure-attention families --
    they have no resident state."""
    mixer = _family_mixer(cfg)
    if mixer == "rwkv":
        return jax.vmap(lambda _: S.rwkv_state_init(cfg, batch))(
            jnp.arange(cfg.n_layers))
    if mixer == "group":
        layout = _group_layout(cfg)
        n_groups = cfg.n_layers // cfg.attn_every

        def one(_):
            return {f"b{i}": S.mamba_state_init(cfg, batch)
                    for i, (m, _u) in enumerate(layout) if m != "attn"}
        return jax.vmap(one)(jnp.arange(n_groups))
    return None


def _one_kv(cfg, batch, max_len, quantized, kv_group=None):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    if quantized:
        gs = A.kv_scale_cols(hd, kv_group)
        return {
            "k_codes": jnp.zeros(shape, jnp.uint8),
            "v_codes": jnp.zeros(shape, jnp.uint8),
            "k_scale": jnp.ones(shape[:-1] + (gs,), jnp.bfloat16),
            "v_scale": jnp.ones(shape[:-1] + (gs,), jnp.bfloat16),
        }
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16)}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(p, batch, cfg, aux_weight: float = 0.01, policy=None):
    logits, _, aux = lm_apply(p, batch, cfg, mode="train", policy=policy)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux, (ce, aux)
