"""Common layers, quantization-aware.

A Dense weight can be either a plain array (training / QAT plane: fake
quantization happens on the param tree before the forward) or a
``PackedTensor`` (serving plane: weights physically packed in HBM as
low-bit codes; the matmul streams packed words and decodes at compute,
which is what the dry-run memory roofline sees).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import PackedTensor, packed_matmul, should_interpret
from ..parallel.sharding import shard

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm", "embed_init", "embed",
    "ffn_init", "ffn", "rope", "mrope", "rope_freqs", "PACKED_USE_KERNEL",
]

# serving plane: False -> pure-jnp unpack+decode+dot (portable: used by the
# dry-run, where the XLA graph must lower for the host compile target);
# True -> the Pallas rmmec_matmul kernel (real TPU execution).
PACKED_USE_KERNEL = False


def _uniform(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": _uniform(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: jax.Array, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if isinstance(w, PackedTensor):
        y = packed_matmul(x, w, use_ref=not PACKED_USE_KERNEL,
                          interpret=should_interpret())
        y = y.astype(x.dtype)
    else:
        cd = compute_dtype or x.dtype
        y = jnp.dot(x.astype(cd), w.astype(cd))
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def rmsnorm_init(d: int):
    return {"norm_scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * p["norm_scale"]).astype(dt)


def embed_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def embed_logits(p, x: jax.Array) -> jax.Array:
    """Tied read-out: x @ table^T."""
    return jnp.dot(x, p["table"].astype(x.dtype).T)


# ---------------------------------------------------------------------------
# FFN (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------

def ffn_init(key, d: int, d_ff: int, kind: str = "swiglu", out_bias=False):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "gate": dense_init(ks[0], d, d_ff),
            "up": dense_init(ks[1], d, d_ff),
            "down": dense_init(ks[2], d_ff, d, bias=out_bias),
        }
    return {
        "up": dense_init(ks[0], d, d_ff),
        "down": dense_init(ks[1], d_ff, d, bias=out_bias),
    }


def ffn(p, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind in ("swiglu", "geglu"):
        g = dense(p["gate"], x)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * dense(p["up"], x)
    else:
        h = jax.nn.gelu(dense(p["up"], x))
    h = shard(h, "batch", "seq", "ff")
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def _apply_rot(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,Dh/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _apply_rot(x, cos, sin)


def mrope(x: jax.Array, positions3: jax.Array, theta: float,
          sections: Optional[Sequence[int]] = None) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the Dh/2 frequency dims are split into
    (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, Dh); positions3: (3, B, S) int32.
    """
    half = x.shape[-1] // 2
    if sections is None:
        hw = 3 * half // 8
        sections = (half - 2 * hw, hw, hw)   # qwen2-vl: (16,24,24) @ Dh=128
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    sec_id = np.repeat(np.arange(3), np.asarray(sections))       # (half,)
    pos_per_dim = positions3[sec_id]                             # (half,B,S)
    ang = jnp.moveaxis(pos_per_dim, 0, -1).astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _apply_rot(x, cos, sin)
