"""Top-k MoE with sort-based dispatch (static shapes, EP-shardable).

Dispatch is the TPU-friendly sort/scatter formulation (no (tokens, experts,
capacity) one-hot -- that mask is quadratically infeasible at Kimi-K2 scale):

  route -> top-k -> flatten (token, expert) pairs -> sort by expert ->
  positions within expert via counts/cumsum -> scatter into the static
  (E, C, D) expert buffer (capacity-drop beyond C) -> vmapped expert FFN
  (expert dim sharded on 'model' = EP) -> weighted combine scatter-add.

Supports Kimi-style shared experts (always-on dense FFN added to the MoE
output) and Arctic-style dense residual (full FFN in parallel with MoE).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import PackedTensor, to_dense
from ..parallel.sharding import shard
from . import layers as L


def _mat(w, dtype):
    """Expert weight leaf -> dense compute array (decodes PackedTensor:
    HBM holds the packed codes; decode happens at use, per layer)."""
    if isinstance(w, PackedTensor):
        return to_dense(w, dtype)
    return w.astype(dtype)

__all__ = ["moe_init", "moe_apply"]


def _expert_ffn_init(key, d: int, d_ff: int, n: int, kind: str):
    """Stacked expert weights: leading dim = experts."""
    ks = jax.random.split(key, 3)
    scale1, scale2 = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {
        "gate": jax.random.uniform(ks[0], (n, d, d_ff), jnp.float32,
                                   -scale1, scale1),
        "up": jax.random.uniform(ks[1], (n, d, d_ff), jnp.float32,
                                 -scale1, scale1),
        "down": jax.random.uniform(ks[2], (n, d_ff, d), jnp.float32,
                                   -scale2, scale2),
    }
    if kind == "gelu":
        del p["gate"]
    return p


def moe_init(key, cfg):
    ks = jax.random.split(key, 4)
    d_ff = cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": {"w": jax.random.normal(ks[0], (cfg.d_model, cfg.n_experts),
                                          jnp.float32) * 0.02},
        "experts": _expert_ffn_init(ks[1], cfg.d_model, d_ff,
                                    cfg.n_experts, cfg.ffn_kind),
    }
    if cfg.shared_experts:
        p["shared"] = L.ffn_init(ks[2], cfg.d_model,
                                 d_ff * cfg.shared_experts, cfg.ffn_kind)
    if cfg.dense_residual:
        p["residual"] = L.ffn_init(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
    return p


def _expert_ffn(p, x: jax.Array, kind: str) -> jax.Array:
    """x: (E, C, D) -> (E, C, D), batched matmuls over the expert dim."""
    up = jnp.einsum("ecd,edf->ecf", x, p["up"].astype(x.dtype))
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", x, p["gate"].astype(x.dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "experts", None, None)
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))


def _n_groups(n: int, target: int = 4096, cap: int = 512) -> int:
    """Largest power-of-2 group count with >= ``target`` tokens/group."""
    g = 1
    while g * 2 <= cap and n % (g * 2) == 0 and n // (g * 2) >= target:
        g *= 2
    return g


def _expert_ffn_grouped(p, x: jax.Array, kind: str) -> jax.Array:
    """x: (G, E, C, D) -> same, expert dim EP-sharded on 'model'."""
    up = jnp.einsum("gecd,edf->gecf", x, _mat(p["up"], x.dtype))
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("gecd,edf->gecf", x, _mat(p["gate"], x.dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", "experts", None, None)
    return jnp.einsum("gecf,efd->gecd", h, _mat(p["down"], x.dtype))


def moe_apply(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    GROUPED sort-based dispatch: tokens split into G groups sharded on the
    data axes; scatter/gather run *inside* ``jax.vmap`` over groups, so
    GSPMD partitions them group-parallel with no replicated expert buffer
    (a flat global scatter forces exactly that -- observed 12 TB/device on
    kimi-k2 before this formulation).  The (G, E, C, D) buffer is the
    all-to-all'd EP layout: groups on 'data', experts on 'model'.
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.experts_per_tok
    g = _n_groups(n)
    ng = n // g
    xt = x.reshape(g, ng, d)
    xt = shard(xt, "batch", None, None)

    logits = jnp.einsum("gnd,de->gne", xt.astype(jnp.float32),
                        p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,Ng,E)
    top_p, top_i = jax.lax.top_k(probs, k)                       # (G,Ng,K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)            # renorm

    # load-balance aux (switch-style): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, e), axis=2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)

    nk = ng * k
    cap = int(math.ceil(nk / e * cfg.capacity_factor))
    cap = max(cap, 4)

    def dispatch(xg, eg, wg):
        """xg (Ng,D), eg/wg (Ng,K) -> buf (E,C,D), dst, toks, ws."""
        flat_e = eg.reshape(nk)
        toks0 = jnp.repeat(jnp.arange(ng, dtype=jnp.int32), k)
        ws0 = wg.reshape(nk)
        order = jnp.argsort(flat_e)
        es, toks, ws = flat_e[order], toks0[order], ws0[order]
        counts = jnp.bincount(es, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(nk, dtype=jnp.int32) - starts[es].astype(jnp.int32)
        keep = pos < cap
        dst = jnp.where(keep, es * cap + pos, e * cap)           # drop slot
        buf = jnp.zeros((e * cap + 1, d), xg.dtype).at[dst].set(xg[toks])
        return buf[: e * cap].reshape(e, cap, d), dst, toks, ws

    buf, dst, toks, ws = jax.vmap(dispatch)(
        xt, top_i, top_p.astype(x.dtype))                        # (G,E,C,D)
    buf = shard(buf, "batch", "experts", None, None)
    eout = _expert_ffn_grouped(p["experts"], buf, cfg.ffn_kind)  # (G,E,C,D)

    def combine(yg, dstg, toksg, wsg):
        yflat = jnp.concatenate(
            [yg.reshape(e * cap, d), jnp.zeros((1, d), yg.dtype)], 0)
        contrib = yflat[dstg] * wsg[:, None]
        return jnp.zeros((ng, d), yg.dtype).at[toksg].add(contrib)

    out = jax.vmap(combine)(eout, dst, toks, ws)                 # (G,Ng,D)
    out = out.reshape(b, s, d)
    out = shard(out, "batch", "seq", "embed")

    if cfg.shared_experts:
        out = out + L.ffn(p["shared"], x, cfg.ffn_kind)
    if cfg.dense_residual:
        out = out + L.ffn(p["residual"], x, cfg.ffn_kind)
    return out, aux.astype(jnp.float32)
