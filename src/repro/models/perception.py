"""The paper's XR perception workloads: UL-VIO, eye-gaze, classification.

These are the models the paper's accuracy figures (Fig. 5-8) evaluate
under precision sweeps.  Implemented small enough to *train* on CPU in
the benchmarks, structurally faithful:

  * VIO (UL-VIO-like): visual-feature branch (the conv encoder is
    stubbed by the data pipeline's feature projection, matching how the
    assignment stubs modality frontends) + IMU branch + fusion MLP ->
    6-DoF relative pose.  Metrics: translation/rotation RMSE, the paper's
    Fig. 6 axes.
  * Eye-gaze: MLP regressor -> 2-D gaze, MSE (Fig. 7).
  * Classifier (EfficientNet stand-in): small convnet -> 10 classes
    (Fig. 5/8 accuracy axis).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

__all__ = [
    "vio_init", "vio_apply", "vio_loss", "gaze_init", "gaze_apply",
    "classifier_init", "classifier_apply", "classifier_loss",
]


def _mlp_init(key, dims, bias=True):
    ks = jax.random.split(key, len(dims) - 1)
    return {f"fc{i}": L.dense_init(ks[i], dims[i], dims[i + 1], bias=bias)
            for i in range(len(dims) - 1)}


def _mlp(p, x, act=jax.nn.gelu):
    n = len(p)
    for i in range(n):
        x = L.dense(p[f"fc{i}"], x)
        if i < n - 1:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# UL-VIO
# ---------------------------------------------------------------------------

def vio_init(key, feat_dim: int = 256, imu_rate: int = 10, width: int = 128):
    ks = jax.random.split(key, 3)
    return {
        "visual_enc": _mlp_init(ks[0], (feat_dim, width, width)),
        "imu_enc": _mlp_init(ks[1], (imu_rate * 6, width, width)),
        "fusion": _mlp_init(ks[2], (2 * width, width, 6)),
    }


def vio_apply(p, batch: Dict) -> jax.Array:
    v = _mlp(p["visual_enc"], batch["visual"])
    i = _mlp(p["imu_enc"], batch["imu"].reshape(batch["imu"].shape[0], -1))
    return _mlp(p["fusion"], jnp.concatenate([v, i], -1))


def vio_loss(p, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    pred = vio_apply(p, batch)
    err = pred - batch["pose"]
    t_rmse = jnp.sqrt(jnp.mean(jnp.sum(jnp.square(err[:, :3]), -1)))
    r_rmse = jnp.sqrt(jnp.mean(jnp.sum(jnp.square(err[:, 3:]), -1)))
    loss = jnp.mean(jnp.square(err))
    return loss, {"t_rmse": t_rmse, "r_rmse": r_rmse}


# ---------------------------------------------------------------------------
# Eye gaze
# ---------------------------------------------------------------------------

def gaze_init(key, feat_dim: int = 128, width: int = 128):
    return {"mlp": _mlp_init(key, (feat_dim, width, width, 2))}


def gaze_apply(p, feats: jax.Array) -> jax.Array:
    return _mlp(p["mlp"], feats)


# ---------------------------------------------------------------------------
# Object classification (EfficientNet-lite stand-in convnet)
# ---------------------------------------------------------------------------

def _conv_init(key, k, cin, cout):
    scale = 1.0 / (k * k * cin) ** 0.5
    return {"w": jax.random.uniform(key, (k, k, cin, cout), jnp.float32,
                                    -scale, scale),
            "bias": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["bias"]


def classifier_init(key, n_classes: int = 10, width: int = 32):
    ks = jax.random.split(key, 5)
    return {
        "conv0": _conv_init(ks[0], 3, 3, width),
        "conv1": _conv_init(ks[1], 3, width, width * 2),
        "conv2": _conv_init(ks[2], 3, width * 2, width * 4),
        "head": L.dense_init(ks[3], width * 4, n_classes, bias=True),
    }


def classifier_apply(p, images: jax.Array) -> jax.Array:
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(p["conv0"], images, 2))
    x = jax.nn.relu(_conv(p["conv1"], x, 2))
    x = jax.nn.relu(_conv(p["conv2"], x, 2))
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(p["head"], x)


def classifier_loss(p, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = classifier_apply(p, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ce, {"acc": acc}
