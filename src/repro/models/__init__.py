from . import layers, attention, moe, ssm, transformer, zoo  # noqa: F401
