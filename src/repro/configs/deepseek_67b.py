"""deepseek-67b [dense] -- llama-arch, GQA kv=8 [arXiv:2401.02954; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, head_dim=128,
    ffn_kind="swiglu",
    source="arXiv:2401.02954; hf",
)
