"""qwen2-vl-7b [vlm] -- M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Vision patch frontend is a STUB per the assignment: input_specs()
provides precomputed patch embeddings (256-patch span)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    ffn_kind="swiglu", qkv_bias=True,
    frontend="vision", rope_kind="mrope", n_patches=256,
    source="arXiv:2409.12191; hf",
)
