"""jamba-v0.1-52b [hybrid] -- Mamba+attn 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887; hf].  Sub-quadratic (Mamba state +
sparse attention layers): runs the long_500k cell."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    ffn_kind="swiglu",
    n_experts=16, experts_per_tok=2, moe_d_ff=14336, moe_every=2,
    attn_every=8, mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    sub_quadratic=True,
    source="arXiv:2403.19887; hf",
)
