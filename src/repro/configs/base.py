"""Model / shape / run configuration dataclasses.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; the registry in ``__init__`` resolves
``--arch <id>``.  ``reduced()`` derives the CPU-smoke-test variant of any
config (same family and wiring, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "RunConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention flavour
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    rope_kind: str = "default"     # default | mrope
    # --- ffn flavour
    ffn_kind: str = "swiglu"       # swiglu | geglu | gelu
    out_bias: bool = False
    tie_embeddings: bool = False
    # --- MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_experts: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    moe_every: int = 1             # apply MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # --- hybrid (jamba) / ssm
    attn_every: int = 0            # jamba: 1 attention layer per this many
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    # --- modality frontend (stub per assignment)
    frontend: str = "none"         # none | audio | vision
    n_patches: int = 0             # vision: patch-embedding span
    # --- numerics / compile hygiene
    dtype: str = "bfloat16"
    remat: str = "full"            # none | full | dots
    scan_layers: bool = True
    seq_chunk: int = 1024          # attention kv/q chunking (flash-style)
    ssm_chunk: int = 64            # mamba/rwkv remat chunk
    attn_impl: str = "scan"        # scan (online-softmax baseline) |
                                   # triangular (causal-exact FLOPs)
    attn_scores_f32: bool = True   # False: bf16 scores+softmax (halves
                                   # attention HBM traffic; beyond-paper)
    decode_impl: str = "blocked"   # quantized-KV decode path:
                                   # blocked (pure-XLA length-aware
                                   # fori_loop; portable default) |
                                   # flash (fused Pallas kernel,
                                   # kernels/flash_decode -- the TPU path)
    # --- metadata
    sub_quadratic: bool = False    # True -> long_500k cell is runnable
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_attn_layers(self) -> int:
        """Attention-layer count: all layers, or 1 per ``attn_every``
        group for hybrid stacks (the KV-roofline denominator everywhere
        -- roofline.analysis and serve.paged_kv must agree on it)."""
        if self.attn_every == 0:
            return self.n_layers
        return self.n_layers // self.attn_every

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else
                         max(2, self.attn_every)),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            experts_per_tok=min(self.experts_per_tok, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            shared_experts=min(self.shared_experts, 1),
            mamba_d_state=8,
            rwkv_head_dim=32,
            n_patches=min(self.n_patches, 8),
            seq_chunk=32,
            ssm_chunk=8,
            remat="none",
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.n_heads:
            per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads)  # qkv
            per_layer += self.n_heads * hd * d                           # out
        ff_mats = 3 if self.ffn_kind in ("swiglu", "geglu") else 2
        n_attnish = self.n_attn_layers
        n_ssm = L - n_attnish
        if self.family == "ssm":
            n_ssm, n_attnish = L, 0
            per_layer = 0
        total = emb + n_attnish * per_layer
        # ffn/moe per layer
        if self.n_experts:
            moe_layers = L // self.moe_every
            dense_layers = L - moe_layers
            ef = self.moe_d_ff or f
            total += moe_layers * (self.n_experts + self.shared_experts) \
                * ef * d * ff_mats
            total += moe_layers * d * self.n_experts  # router
            if self.dense_residual:
                total += moe_layers * f * d * ff_mats
            total += dense_layers * f * d * ff_mats
        else:
            total += L * f * d * ff_mats
        # ssm/rwkv mixers
        if self.family == "ssm":
            total += L * (d * d * 5 // 1)  # r,k,v,g,o projections approx
            total += L * d * f  # channel mix (2 mats, f=7168/2? keep approx)
        if self.family == "hybrid":
            din = d * self.mamba_expand
            total += n_ssm * (d * din * 2 + din * d + din * self.mamba_d_state * 2)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run knobs consumed by the launcher."""
    arch: str = "qwen2-0.5b"
    shape: str = "train_4k"
    steps: int = 100
    microbatch: int = 0            # 0 -> no gradient accumulation
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    # paper technique
    qat: bool = False
    precision_policy: str = "fp32"   # fp32|fp4|posit8_0|mixed|adaptive
    target_avg_bits: float = 6.0
    # distributed tricks
    grad_compression: str = "none"   # none | posit8
    opt_state_dtype: str = "float32" # float32 | bfloat16 | posit8 (8-bit Adam)
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    quantize_kv: bool = False        # posit8 KV cache (serving)
