"""arctic-480b [moe] -- 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    ffn_kind="swiglu",
    n_experts=128, experts_per_tok=2, moe_d_ff=4864, dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
