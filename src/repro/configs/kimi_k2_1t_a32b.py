"""kimi-k2-1t-a32b [moe] -- trillion-param MoE, 384 experts top-8, one
shared expert [arXiv:2501.kimi2; unverified (paper-table)]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    ffn_kind="swiglu",
    n_experts=384, experts_per_tok=8, moe_d_ff=2048, shared_experts=1,
    source="arXiv:2501.kimi2; unverified",
)
