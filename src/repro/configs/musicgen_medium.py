"""musicgen-medium [audio] -- decoder-only over EnCodec tokens, MHA (kv=24)
[arXiv:2306.05284; hf].  The EnCodec frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64,
    ffn_kind="gelu", frontend="audio",
    source="arXiv:2306.05284; hf",
)
