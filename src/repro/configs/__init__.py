"""Architecture registry: ``--arch <id>`` -> ModelConfig.

The 10 assigned architectures (each with its own shape set -- see
``base.SHAPES``) plus the paper's own XR perception workloads (UL-VIO,
eye-gaze, EfficientNet-lite classifier; see ``perception.py``)."""

from __future__ import annotations

from .base import ModelConfig, RunConfig, ShapeConfig, SHAPES
from .gemma_2b import CONFIG as _gemma_2b
from .deepseek_67b import CONFIG as _deepseek_67b
from .command_r_plus_104b import CONFIG as _command_r
from .qwen2_0_5b import CONFIG as _qwen2_05b
from .musicgen_medium import CONFIG as _musicgen
from .kimi_k2_1t_a32b import CONFIG as _kimi_k2
from .arctic_480b import CONFIG as _arctic
from .qwen2_vl_7b import CONFIG as _qwen2_vl
from .rwkv6_1_6b import CONFIG as _rwkv6
from .jamba_v0_1_52b import CONFIG as _jamba

ARCHS = {
    c.name: c for c in (
        _gemma_2b, _deepseek_67b, _command_r, _qwen2_05b, _musicgen,
        _kimi_k2, _arctic, _qwen2_vl, _rwkv6, _jamba,
    )
}

ARCH_IDS = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic sequence mixing (skip for pure
    full-attention archs, per the assignment -- noted in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True


def all_cells():
    """The 40-cell (arch x shape) grid with runnability flags."""
    for arch in ARCH_IDS:
        cfg = ARCHS[arch]
        for sname, shape in SHAPES.items():
            yield arch, sname, cfg, shape, cell_is_runnable(cfg, shape)
