"""rwkv6-1.6b [ssm] -- Finch, data-dependent decay, attention-free
[arXiv:2404.05892; unverified].  Sub-quadratic: runs the long_500k cell."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab=65536, rwkv_head_dim=64,
    sub_quadratic=True,
    source="arXiv:2404.05892; unverified",
)
