"""Standalone SIMD decode (dequantization) kernel.

Streams packed uint32 words from HBM and writes decoded floats -- the
input-processing stage of the NPE in isolation.  Used when a consumer
needs materialized weights (e.g. one-time decode at model load, or
debugging), and as the unit-bench for decode throughput.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt
from ..core.formats import FormatSpec
from ..core.packing import lanes_per_word

__all__ = ["dequant_kernel", "dequant_pallas"]


def dequant_kernel(w_ref, s_ref, o_ref, *, spec: FormatSpec):
    per = lanes_per_word(spec.bits)
    words = w_ref[...]
    shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(spec.bits))
    codes = (words[:, :, None] >> shifts) & jnp.uint32((1 << spec.bits) - 1)
    codes = codes.reshape(words.shape[0], words.shape[1] * per)
    o_ref[...] = fmt.decode_bits(spec, codes, jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("spec", "bk", "bn", "interpret"))
def dequant_pallas(w_words: jax.Array, scales: jax.Array, *,
                   spec: FormatSpec, bk: int = 256, bn: int = 512,
                   interpret: bool = False) -> jax.Array:
    """(K, N/per) uint32 + (1, N) scales -> (K, N) f32."""
    per = lanes_per_word(spec.bits)
    k, nw = w_words.shape
    n = nw * per
    assert k % bk == 0 and n % bn == 0
    return pl.pallas_call(
        functools.partial(dequant_kernel, spec=spec),
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn // per), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=interpret,
    )(w_words, scales)
