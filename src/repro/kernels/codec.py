"""Standalone SIMD decode (dequantization) kernel.

Streams packed uint32 words from HBM and writes decoded floats -- the
input-processing stage of the NPE in isolation.  Used when a consumer
needs materialized weights (e.g. one-time decode at model load, or
debugging), and as the unit-bench for decode throughput.  Format decode
goes through the codec registry (``core.codec``), which under tracing
always picks the kernel-safe branch-free path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import codec as codec_mod
from ..core.formats import FormatSpec
from ..core.packing import lanes_per_word

__all__ = ["dequant_kernel", "dequant_pallas"]


def dequant_kernel(w_ref, s_ref, o_ref, *, spec: FormatSpec,
                   group: Optional[int]):
    per = lanes_per_word(spec.bits)
    words = w_ref[...]
    shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(spec.bits))
    codes = (words[:, :, None] >> shifts) & jnp.uint32((1 << spec.bits) - 1)
    codes = codes.reshape(words.shape[0], words.shape[1] * per)
    w = codec_mod.decode(spec, codes, jnp.float32)
    s = s_ref[...]
    if group is not None:
        bk, bn = w.shape
        s = jnp.broadcast_to(s[:, None, :], (bk // group, group, bn)) \
            .reshape(bk, bn)
    o_ref[...] = w * s


@functools.partial(jax.jit, static_argnames=("spec", "bk", "bn", "group",
                                             "interpret"))
def dequant_pallas(w_words: jax.Array, scales: jax.Array, *,
                   spec: FormatSpec, bk: int = 256, bn: int = 512,
                   group: Optional[int] = None,
                   interpret: bool = False) -> jax.Array:
    """(K, N/per) uint32 + (G, N) scales -> (K, N) f32.

    G = 1 is per-channel; G = K/group gives each K-group its own scale
    row (``bk`` must be a multiple of ``group``).
    """
    per = lanes_per_word(spec.bits)
    k, nw = w_words.shape
    n = nw * per
    assert k % bk == 0 and n % bn == 0
    if group is None:
        s_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    else:
        assert bk % group == 0 and scales.shape[0] == k // group, \
            (bk, group, scales.shape)
        s_spec = pl.BlockSpec((bk // group, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(dequant_kernel, spec=spec, group=group),
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn // per), lambda i, j: (i, j)),
            s_spec,
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=interpret,
    )(w_words, scales)
