"""RMMEC packed mixed-precision GEMM -- the XR-NPE MAC array on TPU.

The ASIC datapath: packed low-bit operands stream in, the RMMEC block
decodes mantissa/exponent per ``prec_sel``, zero operands power-gate their
multiplier, and a quire accumulates.  The TPU port keeps the same stages,
re-cut for the HBM->VMEM->MXU hierarchy:

  HBM traffic   : weights live PACKED in HBM (uint32 words holding 8x4b /
                  4x8b / 2x16b codes) -- this is the bandwidth saving.
  VMEM decode   : each weight block is unpacked + decoded *in VMEM* by the
                  codec registry (``core.codec``), which under tracing
                  always picks the branch-free integer datapath (the RMMEC
                  analogue; one static mode per compiled kernel, mirroring
                  the hardware ``prec_sel`` register).
  power gating  : a per-(K-block, N-block) nonzero mask lets ``pl.when``
                  skip the MXU work of all-zero weight blocks entirely --
                  the dark-silicon reduction, as compute-cycle gating.
  quire         : f32 MXU accumulation; products of <=12-bit mantissas
                  accumulate exactly per step (bit-exact quire semantics for
                  the Posit(8,0) path is provided by the separate
                  ``quire_dot`` kernel).
  morphable tile: block shapes are chosen per precision mode so the packed
                  working set fills VMEM and MXU dims stay 128-aligned --
                  the 8x8/16x16 morphable-array analogue.

Grid is (M/bm, N/bn, K/bk) with the K axis innermost ('arbitrary'); the
output block is revisited across K steps and used as the accumulator.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import codec as codec_mod
from ..core.formats import FormatSpec
from ..core.packing import lanes_per_word

__all__ = ["rmmec_matmul_kernel", "rmmec_matmul_pallas", "default_blocks"]

# renamed across JAX versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def default_blocks(spec: FormatSpec) -> Tuple[int, int, int]:
    """Morphable tiling: (bm, bk, bn) per precision mode.

    Lower-precision modes pack more codes per HBM word, so a larger K block
    keeps the MXU fed from the same packed VMEM budget.
    """
    if spec.bits <= 4:
        return (128, 1024, 256)
    if spec.bits <= 8:
        return (128, 512, 256)
    return (128, 512, 128)


def _compute_dtype(spec: FormatSpec, x_dtype):
    # Follow the activation dtype: bf16 activations get the 2x-rate MXU
    # path (<=8-bit formats decode *exactly* into bf16 -- <=6 mantissa
    # bits); f32 activations keep full precision.  Posit16 always decodes
    # to f32 (12 fraction bits exceed bf16's 8).
    if x_dtype == jnp.bfloat16 and spec.bits <= 8:
        return jnp.bfloat16
    return jnp.float32


def rmmec_matmul_kernel(mask_ref, x_ref, w_ref, s_ref, o_ref, *,
                        spec: FormatSpec, n_block: int, k_steps: int,
                        group: Optional[int]):
    """One (bm, bn) output block; K-step accumulation with block gating.

    ``group`` None: per-channel scales, applied once at output (the seed
    path).  ``group`` set: the scale block holds bk/group rows and is
    applied to the decoded weights *inside* the quire accumulation --
    each K-block's contribution enters the accumulator already on its
    own group grid (the scale-accumulate stage of the paper's datapath,
    at K-group granularity).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    j = pl.program_id(1)
    gate = mask_ref[k, j]

    @pl.when(gate != 0)
    def _mac():
        per = lanes_per_word(spec.bits)
        words = w_ref[...]  # (bk, bn // per) uint32
        shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(spec.bits))
        codes = (words[:, :, None] >> shifts) & jnp.uint32((1 << spec.bits) - 1)
        codes = codes.reshape(words.shape[0], words.shape[1] * per)
        cdt = _compute_dtype(spec, x_ref.dtype)
        # RMMEC decode, in VMEM -- codec picks the branch-free path
        w = codec_mod.decode(spec, codes, dtype=cdt)
        if group is not None:
            # per-group scale inside the accumulation (po2 scales are
            # exact in bf16, so the fast path keeps its 2x MXU rate)
            s = s_ref[...].astype(cdt)               # (bk // group, bn)
            bk, bn = w.shape
            w = (w.reshape(bk // group, group, bn)
                 * s[:, None, :]).reshape(bk, bn)
        x = x_ref[...].astype(cdt)
        o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    if group is None:
        @pl.when(k == k_steps - 1)
        def _scale():
            # output processing stage: apply the per-column
            # (exponent-shift) scale once, after quire accumulation.
            o_ref[...] = o_ref[...] * s_ref[...].astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "bm", "bk", "bn", "group", "interpret"),
)
def rmmec_matmul_pallas(x: jax.Array, w_words: jax.Array, scales: jax.Array,
                        mask: jax.Array, *, spec: FormatSpec,
                        bm: int, bk: int, bn: int,
                        group: Optional[int] = None,
                        interpret: bool = False) -> jax.Array:
    """x:(M,K) float  @  packed w:(K, N/per) uint32  -> (M, N) f32.

    scales: (G, N) f32 dequant scales -- G=1 per-output-channel (applied
            once at output), G=K/group per-(K-group, channel) (applied
            per K-block inside the accumulation).
    mask:   (K/bk, N/bn) int32 nonzero-block map (0 -> power-gated).
    All dims must already be padded to block multiples (see ops.py).
    """
    m, kdim = x.shape
    per = lanes_per_word(spec.bits)
    n = w_words.shape[1] * per
    assert m % bm == 0 and kdim % bk == 0 and n % bn == 0, (m, kdim, n)
    if group is not None:
        assert bk % group == 0 and scales.shape[0] == kdim // group, \
            (bk, group, scales.shape)
    grid = (m // bm, n // bn, kdim // bk)
    kernel = functools.partial(rmmec_matmul_kernel, spec=spec,
                               n_block=bn, k_steps=grid[2], group=group)
    if group is None:
        s_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    else:
        s_spec = pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(mask.shape, lambda i, j, k: (0, 0)),       # gate map
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),          # x
            pl.BlockSpec((bk, bn // per), lambda i, j, k: (k, j)),   # packed w
            s_spec,                                                  # scales
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(mask, x, w_words, scales)
