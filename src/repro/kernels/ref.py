"""Pure-jnp oracles for every kernel in this package.

Each Pallas kernel is validated against these in tests (shape/dtype sweeps,
``interpret=True`` on CPU).  The oracles are deliberately naive and
readable; ``core.quire`` provides the even-stronger exact-integer oracle
for the quire kernel.  Scales may be per-channel (G=1 rows) or per-K-group
(G rows): the oracles expand them generically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codec as codec_mod
from ..core import formats as fmt
from ..core import quant
from ..core.formats import FormatSpec
from ..core.packing import unpack

__all__ = ["rmmec_matmul_ref", "quire_dot_ref", "dequant_ref",
           "flash_decode_ref", "paged_flash_decode_ref",
           "paged_prefill_ref"]


def _expand_scales(scales: jax.Array, k_rows: int) -> jax.Array:
    """(..., G, N) scales -> per-row multiplier over ``k_rows`` decoded
    rows (G=1 broadcasts; single implementation in core.quant)."""
    return quant.expand_group_scales(scales, k_rows // scales.shape[-2],
                                     k_rows)


def dequant_ref(w_words: jax.Array, scales: jax.Array, spec: FormatSpec,
                n: int) -> jax.Array:
    codes = unpack(w_words, spec.bits, n)
    w = codec_mod.decode(spec, codes).astype(jnp.float32)
    return w * _expand_scales(scales, codes.shape[-2])


def rmmec_matmul_ref(x: jax.Array, w_words: jax.Array, scales: jax.Array,
                     spec: FormatSpec, n: int) -> jax.Array:
    """Unpack -> decode -> plain f32 matmul.  The block-gating mask is
    semantically a no-op (gated blocks are all-zero), so the oracle
    ignores it.  Handles K-padded packed weights (pad rows are zero)."""
    w = dequant_ref(w_words, scales, spec, n)
    return jnp.dot(x.astype(jnp.float32), w[: x.shape[-1]])


def _dequant_kv_ref(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """(..., Dh) posit8 codes + (..., Gs) scales -> (..., Dh) f32."""
    dh, gs = codes.shape[-1], scale.shape[-1]
    x = codec_mod.decode(fmt.POSIT8, codes.astype(jnp.int32), jnp.float32)
    return x * jnp.repeat(scale.astype(jnp.float32), dh // gs, axis=-1)


def flash_decode_ref(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                     v_codes: jax.Array, v_scale: jax.Array, pos,
                     softcap: float = 0.0, pad=None) -> jax.Array:
    """Naive full-softmax oracle for the fused flash-decode kernel:
    dequantize the WHOLE cache, one masked softmax over all of T.
    ``pad``: optional (B,) left-pad widths -- request b also masks
    slots below ``pad[b]`` (the ragged static-batch case).
    Shapes match :func:`..flash_decode.flash_decode_pallas`."""
    b, kh, g, dh = q.shape
    k = _dequant_kv_ref(k_codes, k_scale)                # (B, T, Kh, Dh)
    v = _dequant_kv_ref(v_codes, v_scale)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k)
    s = s / jnp.sqrt(jnp.float32(dh))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    tpos = jnp.arange(k_codes.shape[1])
    live = tpos[None, None, None, :] <= pos
    if pad is not None:
        live = live & (tpos[None, None, None, :] >=
                       jnp.asarray(pad)[:, None, None, None])
    s = jnp.where(live, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p, v)


def paged_flash_decode_ref(q: jax.Array, k_codes: jax.Array,
                           k_scale: jax.Array, v_codes: jax.Array,
                           v_scale: jax.Array, page_table: jax.Array,
                           positions: jax.Array,
                           softcap: float = 0.0) -> jax.Array:
    """Naive oracle for the paged kernel: gather every request's pages
    back into a contiguous cache, then one masked softmax per request
    with its own ``positions[i]``.  Shapes match
    :func:`..flash_decode.paged_flash_decode_pallas` (pool pages
    (P, page, Kh, Dh), page table (B, NP), positions (B,))."""
    b, kh, g, dh = q.shape
    page = k_codes.shape[1]
    t = page_table.shape[1] * page
    # (B, NP, page, Kh, X) -> (B, T, Kh, X): request-contiguous layout
    def gather(pool):
        x = pool[page_table]
        return x.reshape(b, t, *pool.shape[2:])
    k = _dequant_kv_ref(gather(k_codes), gather(k_scale))
    v = _dequant_kv_ref(gather(v_codes), gather(v_scale))
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k)
    s = s / jnp.sqrt(jnp.float32(dh))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    tpos = jnp.arange(t)
    s = jnp.where(tpos[None, None, None, :] <= positions[:, None, None, None],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p, v)


def paged_prefill_ref(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                      v_codes: jax.Array, v_scale: jax.Array,
                      page_table: jax.Array, start: jax.Array,
                      softcap: float = 0.0) -> jax.Array:
    """Naive oracle for the paged chunk-PREFILL kernel: gather every
    request's pages back into a contiguous cache, then one causally
    masked softmax per (request, chunk row) -- row ``i`` of request
    ``b`` sits at absolute position ``start[b] + i`` and attends to
    logical slots [0, start[b] + i].  Shapes match
    :func:`..flash_decode.paged_flash_prefill_pallas` (q
    (B, C, Kh, G, Dh), pool pages (P, page, Kh, X), page table (B, NP),
    start (B,)); returns (B, C, Kh, G, Dh) f32."""
    b, c, kh, g, dh = q.shape
    page = k_codes.shape[1]
    t = page_table.shape[1] * page

    def gather(pool):
        x = pool[page_table]
        return x.reshape(b, t, *pool.shape[2:])
    k = _dequant_kv_ref(gather(k_codes), gather(k_scale))
    v = _dequant_kv_ref(gather(v_codes), gather(v_scale))
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32), k)
    s = s / jnp.sqrt(jnp.float32(dh))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = start[:, None] + jnp.arange(c)                    # (B, C)
    live = jnp.arange(t)[None, None, None, None, :] \
        <= qpos[:, None, None, :, None]                      # (B,1,1,C,T)
    s = jnp.where(live, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bkgqd", p, v)
    return out.transpose(0, 3, 1, 2, 4)


def quire_dot_ref(a_codes, b_codes) -> np.ndarray:
    """Row-wise posit8 dot in float64 (numpy).  float64 holds every posit8
    product exactly and sums of < 2^40 of them without rounding, so this
    matches the integer quire bit-for-bit in that regime."""
    table = fmt.code_values(fmt.POSIT8).astype(np.float64)
    table = np.where(np.isnan(table), 0.0, table)
    a = table[np.asarray(a_codes) & 0xFF]
    b = table[np.asarray(b_codes) & 0xFF]
    return np.sum(a * b, axis=-1)
