"""Fused quantized-KV flash-decode kernel -- online softmax over posit8
KV blocks, dequantized in VMEM.

The decode roofline is KV + weight bytes.  PR 1 stopped paying bf16 for
the weights; this kernel stops paying it for the KV cache: the posit8
codes (+ po2 group scales) stream from HBM and are decoded per block
*inside* the kernel by the codec registry's branch-free path -- the same
VMEM-decode stage ``rmmec_matmul`` uses for weights, applied to the KV
plane.  The bf16 cache never exists in HBM.

Grid is (B, Kh, T/blk) with the KV-block axis innermost ('arbitrary'):
the (G, Dh) output block is revisited across T steps and the online-
softmax state (f32 accumulator, running max m, normalizer l) lives in
VMEM scratch, carried across grid steps exactly like the K-step
accumulator of ``rmmec_matmul``.

Length-aware block skipping: ``pos`` arrives as a scalar-prefetch
operand, so the KV BlockSpec index maps clamp the T-block index to
``pos // blk``.  Every grid step past the live prefix maps to the SAME
HBM block -- Pallas sees an unchanged block index between consecutive
steps and issues no new DMA -- and ``pl.when`` gates its compute off.  A
step at position ``pos`` therefore moves ceil((pos+1)/blk) KV blocks
instead of ``max_len/blk``, so short sequences in a long cache no
longer pay for ``max_len``.

``attn_decode`` (models/attention.py) carries the pure-XLA analogue (a
``fori_loop`` over the same blocks) for targets where a Pallas call is
not portable -- the dry-run's host-compile path and sharded caches --
mirroring the ``PACKED_USE_KERNEL`` split of the weight plane.

Paged variant (``paged_flash_decode_pallas``): the KV operands are a
POOL of fixed-size pages (page size == the KV block) shared by all
requests, and each request owns a row of a page table.  The
scalar-prefetch clamp generalizes exactly as the PR 2 design predicted:
the block-index clamp ``min(t, pos // blk)`` becomes a GATHER through
the prefetched page-table row, ``page_table[i, min(t, pos[i] // blk)]``
-- dead grid steps still map to the request's last live page, so Pallas
re-uses the resident block and issues no DMA.  ``pos`` is per-request
(a second scalar-prefetch operand): requests at different positions
decode in one batched grid, which is what continuous batching needs.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import codec as codec_mod
from ..core import formats as fmt

__all__ = ["flash_decode_kernel", "flash_decode_pallas", "default_kv_block",
           "paged_flash_decode_kernel", "paged_flash_decode_pallas",
           "paged_flash_prefill_kernel", "paged_flash_prefill_pallas"]

# renamed across JAX versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

_NEG_INF = -1e30


def default_kv_block(max_len: int) -> int:
    """Largest KV block size <= 128 that divides ``max_len`` (128 keeps
    MXU dims aligned while staying fine-grained enough that the live
    prefix ceil((pos+1)/blk) tracks ``pos``, not ``max_len``)."""
    for blk in (128, 64, 32, 16, 8, 4, 2):
        if max_len % blk == 0:
            return blk
    return 1


def _dequant_block(codes_ref, scale_ref, dh: int, gs: int) -> jax.Array:
    """(1, blk, 1, Dh) uint8 codes + (1, blk, 1, Gs) scales -> (blk, Dh)
    f32, decoded in VMEM (codec picks the branch-free path under
    tracing).  Gs = Dh/group scale columns; Gs=1 broadcasts."""
    codes = codes_ref[0, :, 0, :].astype(jnp.int32)
    x = codec_mod.decode(fmt.POSIT8, codes, jnp.float32)
    s = scale_ref[0, :, 0, :].astype(jnp.float32)
    if gs == 1:
        return x * s
    return x * jnp.repeat(s, dh // gs, axis=-1)


def _online_softmax_step(pos_last, qpos, q2, kc_ref, ks_ref, vc_ref, vs_ref,
                         write_out, acc_ref, m_ref, l_ref, *,
                         blk: int, softcap: float, scale: float,
                         pad_lo=None):
    """One grid step of the online-softmax accumulation: init scratch at
    t=0, accumulate the current KV block while any query row is live for
    it, emit the normalized output through ``write_out`` at the last
    step.  ONE copy of the math for the contiguous-decode, paged-decode
    and paged-prefill kernels (the bitwise-parity tests rest on it) --
    they differ only in how the BlockSpec index maps pick the HBM block
    and in the query geometry:

      q2       : (R, Dh) row-flattened query block (decode: R = G;
                 prefill: R = C*G, row = qi*G + gi).
      qpos     : per-row key-visibility horizon, broadcastable against
                 (R, blk) (decode: the scalar ``pos``; prefill:
                 ``start + row // G`` as an (R, 1) column).
      pos_last : scalar max of ``qpos`` -- gates dead grid steps off.
      pad_lo   : optional low key-visibility bound (ragged LEFT-padded
                 batches: slots below the request's pad width are dead).
                 ``None`` (the paged kernels, where rows have no pad)
                 compiles the exact pre-pad mask -- bitwise unchanged.

    With ``pad_lo`` set, blocks that sit ENTIRELY below the pad are
    gated off like blocks past the live horizon (their index map clamps
    them onto the first live block, so they are never fetched either).
    Skipping them is bitwise-identical to masking them: an all-masked
    block leaves m at -1e30 and contributes p-rows that the first live
    block's rescale ``alpha = exp(-1e30 - m_new)`` underflows to +0.0,
    annihilating acc and l exactly -- the gated path just starts from
    the same (acc=0, m=-1e30, l=0) scratch state at that block.
    """
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    live = t * blk <= pos_last
    if pad_lo is not None:
        # block t covers slots [t*blk, (t+1)*blk): it holds a live slot
        # iff its last slot reaches the pad
        live &= (t + 1) * blk > pad_lo

    @pl.when(live)
    def _block():
        dh = q2.shape[-1]
        gs = ks_ref.shape[-1]
        q = q2.astype(jnp.float32)                        # (R, Dh)
        k = _dequant_block(kc_ref, ks_ref, dh, gs)        # (blk, Dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (R, blk)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        kpos = t * blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, _NEG_INF)
        if pad_lo is not None:
            s = jnp.where(kpos >= pad_lo, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = _dequant_block(vc_ref, vs_ref, dh, gs)        # (blk, Dh)
        pv = jnp.dot(p, v, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(t == nt - 1)
    def _finalize():
        write_out(acc_ref[...] / l_ref[...])


def flash_decode_kernel(pos_ref, pad_ref, q_ref, kc_ref, ks_ref, vc_ref,
                        vs_ref, o_ref, acc_ref, m_ref, l_ref, *,
                        blk: int, softcap: float, scale: float):
    """One (B, Kh) cell; online-softmax accumulation over live KV blocks.
    ``pad_ref`` holds per-request left-pad widths ((B,), zeros for a
    non-ragged batch): slots below ``pad_ref[i]`` are masked dead, the
    left-padded twin of the causal mask."""
    pos = pos_ref[0]

    def write_out(out):
        o_ref[0, 0] = out

    _online_softmax_step(pos, pos, q_ref[0, 0], kc_ref, ks_ref, vc_ref,
                         vs_ref, write_out, acc_ref, m_ref, l_ref,
                         blk=blk, softcap=softcap, scale=scale,
                         pad_lo=pad_ref[pl.program_id(0)])


def paged_flash_decode_kernel(pt_ref, pos_ref, q_ref, kc_ref, ks_ref,
                              vc_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref,
                              *, blk: int, softcap: float, scale: float):
    """Paged cell: identical math, but ``pos`` is per-request and the KV
    blocks were gathered through the page table by the index maps (the
    kernel body never sees physical page ids)."""
    pos = pos_ref[pl.program_id(0)]

    def write_out(out):
        o_ref[0, 0] = out

    _online_softmax_step(pos, pos, q_ref[0, 0], kc_ref, ks_ref, vc_ref,
                         vs_ref, write_out, acc_ref, m_ref, l_ref,
                         blk=blk, softcap=softcap, scale=scale)


@functools.partial(jax.jit,
                   static_argnames=("blk", "softcap", "interpret"))
def flash_decode_pallas(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                        v_codes: jax.Array, v_scale: jax.Array,
                        pos: jax.Array, *, pad: Optional[jax.Array] = None,
                        blk: Optional[int] = None,
                        softcap: float = 0.0,
                        interpret: bool = False) -> jax.Array:
    """GQA decode attention straight from posit8 KV codes.

    q                : (B, Kh, G, Dh) float -- one new token's queries,
                       grouped per KV head.
    k_codes/v_codes  : (B, T, Kh, Dh) uint8 posit8 codes (T = max_len).
    k_scale/v_scale  : (B, T, Kh, Gs) po2 dequant scales in the unified
                       ``quant.group_scales`` layout: Gs = Dh/group
                       (Gs = 1 is per-(token, head), the group=Dh case).
    pos              : scalar int32 -- attends to cache slots [0, pos].
    pad              : optional (B,) int32 left-pad widths of a ragged
                       batch -- request i additionally masks slots below
                       ``pad[i]`` (None == an all-zeros pad: the dense
                       static-batch case).  Blocks fully below the pad
                       are never fetched: the index map clamps them onto
                       the first live block (``pad[i] // blk``) exactly
                       like dead blocks past the horizon clamp onto the
                       last live one, so the block index stops changing
                       and Pallas issues no DMA; ``pl.when`` gates their
                       compute off.  A step for row i therefore moves
                       only its ``ceil((pos+1)/blk) - pad[i] // blk``
                       live blocks -- and the output is bitwise the old
                       mask-everything path's (see
                       ``_online_softmax_step``).

    Returns (B, Kh, G, Dh) f32 attention output.
    """
    b, kh, g, dh = q.shape
    t = k_codes.shape[1]
    gs = k_scale.shape[-1]
    if blk is None:
        blk = default_kv_block(t)
    assert t % blk == 0, (t, blk)
    nt = t // blk

    def q_im(i, h, tt, pos_ref, pad_ref):
        return (i, h, 0, 0)

    def kv_im(i, h, tt, pos_ref, pad_ref):
        # clamp dead blocks onto the live window: blocks past the
        # horizon re-map to the last live block and blocks fully below
        # the left pad to the first live one -- either side, the block
        # index stops changing, so Pallas re-uses the resident copy
        # (no DMA).  pad <= pos for any valid row, so lo <= hi.
        return (i, jnp.clip(tt, pad_ref[i] // blk, pos_ref[0] // blk),
                h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, nt),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), q_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), q_im),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # normalizer l
        ],
    )
    kernel = functools.partial(flash_decode_kernel, blk=blk,
                               softcap=float(softcap),
                               scale=1.0 / math.sqrt(dh))
    pos_arr = jnp.asarray(pos, jnp.int32).reshape((1,))
    pad_arr = jnp.zeros((b,), jnp.int32) if pad is None \
        else jnp.asarray(pad, jnp.int32).reshape((b,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, pad_arr, q, k_codes, k_scale, v_codes, v_scale)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_flash_decode_pallas(q: jax.Array, k_codes: jax.Array,
                              k_scale: jax.Array, v_codes: jax.Array,
                              v_scale: jax.Array, page_table: jax.Array,
                              positions: jax.Array, *,
                              softcap: float = 0.0,
                              interpret: bool = False) -> jax.Array:
    """GQA decode attention over a PAGED posit8 KV pool.

    q                : (B, Kh, G, Dh) float -- one new token per request.
    k_codes/v_codes  : (P, page, Kh, Dh) uint8 pool pages (page = KV blk).
    k_scale/v_scale  : (P, page, Kh, Gs) po2 scales, unified layout.
    page_table       : (B, NP) int32 -- request i's logical block t lives
                       in pool page ``page_table[i, t]``; rows are padded
                       with a parking page id past the live prefix.
    positions        : (B,) int32 -- request i attends to logical slots
                       [0, positions[i]].

    The whole page indirection lives in the KV BlockSpec index map: the
    contiguous kernel's clamp ``min(t, pos // blk)`` becomes the gather
    ``page_table[i, min(t, pos[i] // blk)]`` through the two prefetched
    scalar operands.  Past a request's live prefix the gathered page id
    stops changing, so Pallas sees an unchanged block index and issues no
    DMA -- a step still moves only ceil((pos+1)/page) pages per request.

    Returns (B, Kh, G, Dh) f32 attention output.
    """
    b, kh, g, dh = q.shape
    blk = k_codes.shape[1]
    gs = k_scale.shape[-1]
    npp = page_table.shape[1]

    def q_im(i, h, tt, pt_ref, pos_ref):
        return (i, h, 0, 0)

    def kv_im(i, h, tt, pt_ref, pos_ref):
        # the PR 2 clamp, now a gather: dead steps re-read the request's
        # last live page (same block index -> no DMA)
        tc = jnp.minimum(tt, pos_ref[i] // blk)
        return (pt_ref[i * npp + tc], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, npp),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), q_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), q_im),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # normalizer l
        ],
    )
    kernel = functools.partial(paged_flash_decode_kernel, blk=blk,
                               softcap=float(softcap),
                               scale=1.0 / math.sqrt(dh))
    pt_flat = page_table.reshape(-1).astype(jnp.int32)
    pos_arr = jnp.asarray(positions, jnp.int32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt_flat, pos_arr, q, k_codes, k_scale, v_codes, v_scale)


def paged_flash_prefill_kernel(pt_ref, start_ref, q_ref, kc_ref, ks_ref,
                               vc_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref,
                               *, blk: int, c: int, g: int, softcap: float,
                               scale: float):
    """One (B, Kh) cell of the paged chunk-PREFILL kernel: the SAME
    online-softmax body as the decode kernels, widened to a (C*G, Dh)
    query block (row ``qi*G + gi``); the causal horizon of row ``r`` is
    ``start + r // G``."""
    start = start_ref[pl.program_id(0)]
    dh = q_ref.shape[-1]
    q2 = q_ref[0, :, 0].reshape(c * g, dh)
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (c * g, 1), 0) // g

    def write_out(out):
        o_ref[0, :, 0] = out.reshape(c, g, dh)

    _online_softmax_step(start + c - 1, qpos, q2, kc_ref, ks_ref, vc_ref,
                         vs_ref, write_out, acc_ref, m_ref, l_ref,
                         blk=blk, softcap=softcap, scale=scale)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_flash_prefill_pallas(q: jax.Array, k_codes: jax.Array,
                               k_scale: jax.Array, v_codes: jax.Array,
                               v_scale: jax.Array, page_table: jax.Array,
                               start: jax.Array, *,
                               softcap: float = 0.0,
                               interpret: bool = False) -> jax.Array:
    """Paged chunk-PREFILL attention over a posit8 KV pool: the prefill
    twin of :func:`paged_flash_decode_pallas`.

    q                : (B, C, Kh, G, Dh) float -- one CHUNK of C queries
                       per request, at absolute positions
                       ``start[i] .. start[i] + C - 1``.
    k_codes/v_codes  : (P, page, Kh, Dh) uint8 pool pages (page = KV blk).
    k_scale/v_scale  : (P, page, Kh, Gs) po2 scales, unified layout.
    page_table       : (B, NP) int32 -- the request's previously written
                       pages plus its own (just-written) chunk pages;
                       rows padded with a parking page id.
    start            : (B,) int32 -- query i*? attends to logical slots
                       [0, start[i] + row] causally.

    Identical page indirection to the decode kernel: the KV index map
    gathers ``page_table[i, min(t, (start[i]+C-1) // blk)]``, so grid
    steps past the chunk's last live page re-read the resident block
    (no DMA) and ``pl.when`` gates their compute off.  A chunk step
    moves ceil((start+C)/page) pages -- the chunk's causal prefix --
    regardless of NP.

    Returns (B, C, Kh, G, Dh) f32 attention output.
    """
    b, c, kh, g, dh = q.shape
    blk = k_codes.shape[1]
    gs = k_scale.shape[-1]
    npp = page_table.shape[1]

    def q_im(i, h, tt, pt_ref, start_ref):
        return (i, 0, h, 0, 0)

    def kv_im(i, h, tt, pt_ref, start_ref):
        tc = jnp.minimum(tt, (start_ref[i] + c - 1) // blk)
        return (pt_ref[i * npp + tc], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, npp),
        in_specs=[
            pl.BlockSpec((1, c, 1, g, dh), q_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
            pl.BlockSpec((1, blk, 1, dh), kv_im),
            pl.BlockSpec((1, blk, 1, gs), kv_im),
        ],
        out_specs=pl.BlockSpec((1, c, 1, g, dh), q_im),
        scratch_shapes=[
            pltpu.VMEM((c * g, dh), jnp.float32),   # acc
            pltpu.VMEM((c * g, 1), jnp.float32),    # running max m
            pltpu.VMEM((c * g, 1), jnp.float32),    # normalizer l
        ],
    )
    kernel = functools.partial(paged_flash_prefill_kernel, blk=blk, c=c,
                               g=g, softcap=float(softcap),
                               scale=1.0 / math.sqrt(dh))
    pt_flat = page_table.reshape(-1).astype(jnp.int32)
    start_arr = jnp.asarray(start, jnp.int32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, kh, g, dh), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt_flat, start_arr, q, k_codes, k_scale, v_codes, v_scale)
