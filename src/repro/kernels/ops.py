"""Public ops over the XR-NPE kernels -- the packed-weight data plane.

``prec_sel`` from the paper is the ``spec`` argument here: each format
compiles its own kernel instance (the datapath is statically morphed),
and this module is the mode multiplexer.  On CPU (this container)
kernels run in ``interpret=True``; on TPU they compile to Mosaic.

All format logic goes through the codec registry (``core.codec``): this
module never picks between the table and algorithmic en/decode paths --
the codec does.  The physical weight representation is ``PackedTensor``
v2:

  * rank-generic -- one pack path for 2-D kernel-ready matrices and N-D
    (scan/expert-stacked) weights; leading dims are sliceable by
    ``lax.scan`` exactly like the dense tree;
  * per-group (block-wise) scales along K -- ``group_size`` codes share
    one dequant scale (32/64/128 typical; ``None`` = per-channel, the
    ``group=K`` special case and the bitwise-seed-compatible layout).
    Fine groups track local dynamic range, which is what makes the
    4-bit formats (FP4 / Posit(4,1)) usable on real weights;
  * versioned aux metadata (``version``) so checkpoints round-trip the
    layout across format evolution.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codec as codec_mod
from ..core import quant
from ..core.formats import FormatSpec
from ..core.packing import lanes_per_word, pack, unpack
from . import ref
from .codec import dequant_pallas
from .quire_dot import QUIRE_FRAC_BITS, quire_dot_pallas
from .rmmec_matmul import default_blocks, rmmec_matmul_pallas

__all__ = [
    "PackedTensor", "pack_tensor", "unpack_tensor", "packed_matmul",
    "quire_dot", "dequant", "should_interpret", "to_dense",
    "PACKED_TENSOR_VERSION",
]

PACKED_TENSOR_VERSION = 2


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedTensor:
    """A weight tensor stored as packed low-bit codes + dequant scales.

    words  : (L..., Kp, ceil(Np/per)) uint32 -- the HBM-resident codes
    scales : (L..., G, Np) f32 -- per-(K-group, out-channel) scales;
             G = 1 is per-channel (group=K), G = Kp/group otherwise
    mask   : (L..., Kp/bk, Np/bn) int32 nonzero-block map (power gating)
    shape  : logical (K, N) of one 2-D slice (leading dims untouched)
    spec   : the format (static / aux data)
    group  : K-group size of ``scales`` (None = per-channel)
    version: layout version (checkpoint round-trip compatibility)
    """

    words: jax.Array
    scales: jax.Array
    mask: jax.Array
    shape: Tuple[int, int]
    spec: FormatSpec
    group: Optional[int] = None
    version: int = PACKED_TENSOR_VERSION

    def tree_flatten(self):
        return ((self.words, self.scales, self.mask),
                (self.shape, self.spec, self.group, self.version))

    @classmethod
    def tree_unflatten(cls, aux, children):
        words, scales, mask = children
        return cls(words, scales, mask, *aux)

    @property
    def nbytes_packed(self) -> int:
        return self.words.size * 4 + self.scales.size * 4 + self.mask.size * 4


def pack_tensor(spec: FormatSpec, w: jax.Array,
                scale_method: str = "auto",
                per_channel: bool = True,
                blocks: Optional[Tuple[int, int, int]] = None,
                group_size: Optional[int] = None) -> PackedTensor:
    """Quantize + pack a weight tensor for the serving plane.

    One rank-generic path: the trailing two dims are the logical (K, N)
    matrix; any leading dims are stacked (scan-over-layers / experts)
    and stay sliceable.  2-D inputs additionally get kernel block
    padding + the gating mask (they feed ``rmmec_matmul_pallas``); N-D
    inputs pad only to word/group boundaries (they feed the portable
    ref path / dequant).

    ``group_size``: codes per dequant scale along K (None/0 =
    per-channel).  Kernel block K (``bk``) must be a multiple of it.
    """
    assert w.ndim >= 2, "pack_tensor needs a trailing (K, N) matrix"
    lead, (k, n) = w.shape[:-2], w.shape[-2:]
    per = lanes_per_word(spec.bits)
    g = int(group_size) if group_size else None
    if g is not None and g >= k:
        g = None  # group=K special case: per-channel
    if w.ndim == 2:
        bm, bk, bn = blocks or default_blocks(spec)
        if g is not None and bk % g:
            raise ValueError(f"K block {bk} not a multiple of group {g}")
    else:
        bk, bn = (g or 1), per
    kp, np_ = _round_up(k, bk), _round_up(n, bn)

    # scales on the *logical* tensor (padding never skews a statistic)
    if not per_channel and g is None:
        s = quant.format_scale(spec, w, scale_method)
        scales = jnp.broadcast_to(jnp.asarray(s).reshape(
            (1,) * (w.ndim - 2) + (1, 1)), lead + (1, n))
    else:
        scales = quant.group_scales(spec, w, g, scale_method)
    codes = codec_mod.encode(
        spec, w / quant.expand_group_scales(scales, g, k))

    pad = [(0, 0)] * len(lead) + [(0, kp - k), (0, np_ - n)]
    codes = jnp.pad(codes, pad)
    words = pack(codes, spec.bits)
    # pad scales to the padded layout: extra groups/columns dequant the
    # zero padding codes, so 1.0 is harmless
    g_tot = kp // g if g is not None else 1
    spad = [(0, 0)] * len(lead) + [(0, g_tot - scales.shape[-2]),
                                   (0, np_ - n)]
    scales_p = jnp.pad(scales, spad, constant_values=1.0)

    if w.ndim == 2:
        # nonzero-block map: gate blocks whose codes are all zero
        # (max, not sum: a sum of 16-bit codes overflows int32 per block)
        blk = codes.reshape(kp // bk, bk, np_ // bn, bn)
        mask = (jnp.max(jnp.abs(blk), axis=(1, 3)) > 0).astype(jnp.int32)
    else:
        mask = (jnp.max(jnp.abs(codes), axis=(-2, -1),
                        keepdims=True) > 0).astype(jnp.int32)
    return PackedTensor(words, scales_p, mask, (k, n), spec, g)


def _expand_scales(t: PackedTensor, dtype=jnp.float32) -> jax.Array:
    """Per-row dequant multiplier matching the padded K of ``t.words``."""
    kp = t.words.shape[-2]
    return quant.expand_group_scales(t.scales.astype(dtype),
                                     kp // t.scales.shape[-2], kp)


def to_dense(t: PackedTensor, dtype=jnp.float32) -> jax.Array:
    """Decode a PackedTensor of any rank back to dense float."""
    n_padded = t.words.shape[-1] * lanes_per_word(t.spec.bits)
    codes = unpack(t.words, t.spec.bits, n_padded)
    w = codec_mod.decode(t.spec, codes, dtype=dtype)
    w = w[..., : t.scales.shape[-1]] * _expand_scales(t, dtype)
    return w[..., : t.shape[0], : t.shape[1]]


def unpack_tensor(t: PackedTensor) -> jax.Array:
    """2-D convenience alias of :func:`to_dense` (kept for callers that
    predate the rank-generic path)."""
    return to_dense(t)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret", "use_ref"))
def packed_matmul(x: jax.Array, t: PackedTensor,
                  blocks: Optional[Tuple[int, int, int]] = None,
                  interpret: Optional[bool] = None,
                  use_ref: bool = False) -> jax.Array:
    """x @ W for packed W; x: (..., K) -> (..., N) f32.

    Group scales are applied per K-block inside the kernel's quire
    accumulation (per-channel scales once at output, as before).

    ``use_ref`` selects the pure-jnp oracle path (used by the serving
    plane when lowering for the XLA-only dry-run, where a Pallas call
    would not be portable to the CPU compile target).
    """
    if interpret is None:
        interpret = should_interpret()
    k, n = t.shape
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(m, x.shape[-1])  # keep caller dtype: bf16 x => bf16 MXU path
    if use_ref:
        out = ref.rmmec_matmul_ref(x2, t.words, t.scales, t.spec,
                                   t.scales.shape[1])[:, :n]
        return out.reshape(*lead, n)
    bm, bk, bn = blocks or default_blocks(t.spec)
    if t.group is not None and bk % t.group:
        raise ValueError(f"K block {bk} not a multiple of group {t.group}")
    mp = _round_up(m, bm)
    x2 = jnp.pad(x2, ((0, mp - m), (0, t.words.shape[0] - k)))
    out = rmmec_matmul_pallas(x2, t.words, t.scales, t.mask, spec=t.spec,
                              bm=bm, bk=bk, bn=bn, group=t.group,
                              interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def quire_dot(a_codes: jax.Array, b_codes: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    """Bit-exact Posit(8,0) row-wise dot: (B, K) codes x2 -> (B,) f32."""
    if interpret is None:
        interpret = should_interpret()
    b, k = a_codes.shape
    bb, bk = 8, 512
    bp, kp = _round_up(b, bb), _round_up(k, bk)
    ap = jnp.pad(a_codes, ((0, bp - b), (0, kp - k)))
    bpc = jnp.pad(b_codes, ((0, bp - b), (0, kp - k)))
    hi, lo = quire_dot_pallas(ap.astype(jnp.int32), bpc.astype(jnp.int32),
                              bb=bb, bk=bk, interpret=interpret)
    return quire_combine(hi, lo)[:b]


def quire_combine(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Fold the two int32 quire limbs into f32 (the single final rounding)."""
    return (hi[:, 0].astype(jnp.float32)
            + lo[:, 0].astype(jnp.float32) * (2.0 ** -QUIRE_FRAC_BITS))


def dequant(t: PackedTensor, interpret: Optional[bool] = None) -> jax.Array:
    """Materialize a 2-D PackedTensor to f32 via the decode kernel."""
    if interpret is None:
        interpret = should_interpret()
    kp = t.words.shape[0]
    npad = t.scales.shape[1]
    per = lanes_per_word(t.spec.bits)
    g = t.group
    kcands = (256, 128, 64, 32, 16, 8, 4, 2, 1) if g is None else \
        tuple(c for c in (256, 128, 64, 32) if c % g == 0) + (g,)
    bk = 256 if (kp % 256 == 0 and (g is None or 256 % g == 0)) \
        else _first_divisor(kp, kcands)
    bn = 512 if npad % 512 == 0 else _first_divisor(npad, (256, 128, 64, 32, 16, 8))
    bn = max(bn, per)
    out = dequant_pallas(t.words, t.scales, spec=t.spec, bk=bk, bn=bn,
                         group=g, interpret=interpret)
    return out[: t.shape[0], : t.shape[1]]


def _first_divisor(n: int, cands) -> int:
    for c in cands:
        if n % c == 0:
            return c
    return 1
