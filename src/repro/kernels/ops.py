"""Public ops over the XR-NPE kernels: padding, packing, dispatch.

``prec_sel`` from the paper is the ``spec`` argument here: each format
compiles its own kernel instance (the datapath is statically morphed), and
this module is the mode multiplexer.  On CPU (this container) kernels run
in ``interpret=True``; on TPU they compile to Mosaic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import formats as fmt
from ..core import quant
from ..core.formats import FormatSpec
from ..core.packing import lanes_per_word, pack, packed_last_dim, unpack
from . import ref
from .codec import dequant_pallas
from .quire_dot import QUIRE_FRAC_BITS, quire_dot_pallas
from .rmmec_matmul import default_blocks, rmmec_matmul_pallas

__all__ = [
    "PackedTensor", "pack_tensor", "unpack_tensor", "packed_matmul",
    "quire_dot", "dequant", "should_interpret", "to_dense",
]


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedTensor:
    """A weight matrix stored as packed low-bit codes + dequant scales.

    words  : (K, ceil(N/per)) uint32 -- the HBM-resident representation
    scales : (1, N) f32 per-output-channel scale
    mask   : (ceil(K/gk), ceil(N/gn)) int32 nonzero-block map (power gating)
    shape  : logical (K, N)
    spec   : the format (static / aux data)
    """

    words: jax.Array
    scales: jax.Array
    mask: jax.Array
    shape: Tuple[int, int]
    spec: FormatSpec

    def tree_flatten(self):
        return (self.words, self.scales, self.mask), (self.shape, self.spec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        words, scales, mask = children
        return cls(words, scales, mask, aux[0], aux[1])

    @property
    def nbytes_packed(self) -> int:
        return self.words.size * 4 + self.scales.size * 4 + self.mask.size * 4


def pack_tensor(spec: FormatSpec, w: jax.Array,
                scale_method: str = "auto",
                per_channel: bool = True,
                blocks: Optional[Tuple[int, int, int]] = None) -> PackedTensor:
    """Quantize + pack a weight matrix for the serving plane.

    2-D (K, N): full treatment -- kernel-ready block padding + gating mask.
    N-D (L..., K, N) stacked scan/expert weights: packed per 2-D slice
    along the last axis (words (L..., K, N/per), scales (L..., 1, N));
    consumed by the portable ref path / dequant, leading dims sliceable by
    lax.scan.  ``shape`` records the logical (K, N) of one slice.
    """
    if w.ndim == 2:
        k, n = w.shape
        bm, bk, bn = blocks or default_blocks(spec)
        axis = 0 if per_channel else None
        scales = quant.format_scale(spec, w, scale_method, axis=axis)
        scales = jnp.broadcast_to(jnp.asarray(scales).reshape(1, -1), (1, n))
        codes = fmt.encode_bits(spec, w / scales)
        kp, np_ = _round_up(k, bk), _round_up(n, bn)
        codes = jnp.pad(codes, ((0, kp - k), (0, np_ - n)))
        words = pack(codes, spec.bits)
        scales_p = jnp.pad(scales, ((0, 0), (0, np_ - n)),
                           constant_values=1.0)
        # nonzero-block map: gate blocks whose codes are all zero
        # (max, not sum: a sum of 16-bit codes overflows int32 per block)
        blk = codes.reshape(kp // bk, bk, np_ // bn, bn)
        mask = (jnp.max(jnp.abs(blk), axis=(1, 3)) > 0).astype(jnp.int32)
        return PackedTensor(words, scales_p, mask, (k, n), spec)
    assert w.ndim >= 3
    k, n = w.shape[-2:]
    lead = w.shape[:-2]
    scales = quant.format_scale(spec, w, scale_method, axis=-2) \
        if per_channel else quant.format_scale(spec, w, scale_method)
    scales = jnp.broadcast_to(jnp.asarray(scales), lead + (1, n))
    codes = fmt.encode_bits(spec, w / scales)
    per = lanes_per_word(spec.bits)
    npad = _round_up(n, per)
    if npad != n:
        padw = [(0, 0)] * (w.ndim - 1) + [(0, npad - n)]
        codes = jnp.pad(codes, padw)
    words = pack(codes, spec.bits)
    mask = jnp.ones(lead + (1, 1), jnp.int32)
    return PackedTensor(words, scales, mask, (k, n), spec)


def to_dense(t: PackedTensor, dtype=jnp.float32) -> jax.Array:
    """Decode a PackedTensor of any rank back to dense float."""
    n_padded = t.words.shape[-1] * lanes_per_word(t.spec.bits)
    codes = unpack(t.words, t.spec.bits, n_padded)
    w = fmt.decode_bits(t.spec, codes, dtype=dtype)
    w = w[..., : t.scales.shape[-1]] * t.scales.astype(dtype)
    return w[..., : t.shape[0], : t.shape[1]]


def unpack_tensor(t: PackedTensor) -> jax.Array:
    kp = t.words.shape[0]
    npad = t.scales.shape[1]
    codes = unpack(t.words, t.spec.bits, npad)
    w = fmt.decode(t.spec, codes) * t.scales
    return w[: t.shape[0], : t.shape[1]]


@functools.partial(jax.jit, static_argnames=("blocks", "interpret", "use_ref"))
def packed_matmul(x: jax.Array, t: PackedTensor,
                  blocks: Optional[Tuple[int, int, int]] = None,
                  interpret: Optional[bool] = None,
                  use_ref: bool = False) -> jax.Array:
    """x @ W for packed W; x: (..., K) -> (..., N) f32.

    ``use_ref`` selects the pure-jnp oracle path (used by the serving plane
    when lowering for the XLA-only dry-run, where a Pallas call would not
    be portable to the CPU compile target).
    """
    if interpret is None:
        interpret = should_interpret()
    k, n = t.shape
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(m, x.shape[-1])  # keep caller dtype: bf16 x => bf16 MXU path
    if use_ref:
        out = ref.rmmec_matmul_ref(x2, t.words, t.scales, t.spec,
                                   t.scales.shape[1])[:, :n]
        return out.reshape(*lead, n)
    bm, bk, bn = blocks or default_blocks(t.spec)
    mp = _round_up(m, bm)
    x2 = jnp.pad(x2, ((0, mp - m), (0, t.words.shape[0] - k)))
    out = rmmec_matmul_pallas(x2, t.words, t.scales, t.mask, spec=t.spec,
                              bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def quire_dot(a_codes: jax.Array, b_codes: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    """Bit-exact Posit(8,0) row-wise dot: (B, K) codes x2 -> (B,) f32."""
    if interpret is None:
        interpret = should_interpret()
    b, k = a_codes.shape
    bb, bk = 8, 512
    bp, kp = _round_up(b, bb), _round_up(k, bk)
    ap = jnp.pad(a_codes, ((0, bp - b), (0, kp - k)))
    bpc = jnp.pad(b_codes, ((0, bp - b), (0, kp - k)))
    hi, lo = quire_dot_pallas(ap.astype(jnp.int32), bpc.astype(jnp.int32),
                              bb=bb, bk=bk, interpret=interpret)
    return quire_combine(hi, lo)[:b]


def quire_combine(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Fold the two int32 quire limbs into f32 (the single final rounding)."""
    return (hi[:, 0].astype(jnp.float32)
            + lo[:, 0].astype(jnp.float32) * (2.0 ** -QUIRE_FRAC_BITS))


def dequant(t: PackedTensor, interpret: Optional[bool] = None) -> jax.Array:
    """Materialize a PackedTensor to f32 via the decode kernel."""
    if interpret is None:
        interpret = should_interpret()
    kp = t.words.shape[0]
    npad = t.scales.shape[1]
    per = lanes_per_word(t.spec.bits)
    bk = 256 if kp % 256 == 0 else _first_divisor(kp, (128, 64, 32, 16, 8, 4, 2, 1))
    bn = 512 if npad % 512 == 0 else _first_divisor(npad, (256, 128, 64, 32, 16, 8))
    bn = max(bn, per)
    out = dequant_pallas(t.words, t.scales, spec=t.spec, bk=bk, bn=bn,
                         interpret=interpret)
    return out[: t.shape[0], : t.shape[1]]


def _first_divisor(n: int, cands) -> int:
    for c in cands:
        if n % c == 0:
            return c
    return 1
