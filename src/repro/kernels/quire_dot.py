"""Quire-exact Posit(8,0) batched dot product on the VPU.

The XR-NPE accumulates posit products in a quire (wide fixed point), so a
dot product rounds exactly once.  f32 MXU accumulation is *almost* that --
each product is exact, but long sums can round.  This kernel reproduces
true quire semantics with integer accumulators:

  * a Posit(8,0) value is M/32 * 2^k, M in [32,63], k in [-6,6]; products
    are exact in f32 (<= 12 significant bits each, 22 < 24 total);
  * each product p is split into hi = round(p) and lo = round((p-hi)*2^22),
    both int32-exact;
  * hi and lo accumulate in two int32 lanes -- the quire limbs -- with a
    carry fold every K step so ``lo`` stays bounded;
  * the single final rounding happens outside the kernel when the limbs
    are combined (ops.quire_combine).

Layout: each row is one MAC lane of the SIMD array; grid is
(B/bb, K/bk) with K innermost, outputs revisited as accumulators.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import codec as codec_mod
from ..core import formats as fmt

# renamed across JAX versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["quire_dot_kernel", "quire_dot_pallas", "QUIRE_FRAC_BITS"]

QUIRE_FRAC_BITS = 22  # lsb of the lo limb = 2^-22 (posit8 product lsb)


def quire_dot_kernel(a_ref, b_ref, hi_ref, lo_ref, *, k_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)

    a = codec_mod.decode(fmt.POSIT8, a_ref[...], dtype=jnp.float32)
    b = codec_mod.decode(fmt.POSIT8, b_ref[...], dtype=jnp.float32)
    p = a * b                                     # exact: <=22 sig bits
    hi = jnp.round(p)                             # integer part, exact
    lo = jnp.round((p - hi) * (2.0 ** QUIRE_FRAC_BITS))  # fractional limb
    hi_ref[...] += jnp.sum(hi, axis=-1, keepdims=True).astype(jnp.int32)
    lo_sum = lo_ref[...] + jnp.sum(lo, axis=-1, keepdims=True).astype(jnp.int32)
    # carry fold: keep |lo| < 2^22 so the next block's partial sums
    # (<= bk * 2^21) never overflow int32.
    carry = lo_sum >> QUIRE_FRAC_BITS            # arithmetic shift
    hi_ref[...] += carry
    lo_ref[...] = lo_sum - (carry << QUIRE_FRAC_BITS)


@functools.partial(jax.jit, static_argnames=("bb", "bk", "interpret"))
def quire_dot_pallas(a_codes: jax.Array, b_codes: jax.Array, *,
                     bb: int = 8, bk: int = 512,
                     interpret: bool = False):
    """a,b: (B, K) int32 posit8 codes -> (hi, lo) int32 quire limbs (B, 1).

    Exact value of row i = hi[i] + lo[i] * 2^-22 (combine in ops.py).
    B, K must be padded to (bb, bk) multiples; zero codes pad harmlessly.
    """
    bsz, kdim = a_codes.shape
    assert a_codes.shape == b_codes.shape
    assert bsz % bb == 0 and kdim % bk == 0
    grid = (bsz // bb, kdim // bk)
    kernel = functools.partial(quire_dot_kernel, k_steps=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bb, bk), lambda i, k: (i, k)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
            jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_codes, b_codes)
