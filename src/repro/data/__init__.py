from .tokens import TokenStream  # noqa: F401
from .vio_data import VIOStream  # noqa: F401
