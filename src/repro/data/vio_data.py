"""Synthetic KITTI-like VIO sequences (the paper's headline workload).

Real KITTI odometry (1241x376 RGB + IMU) is not available offline, so we
generate physically-plausible trajectories: smooth SE(3) motion, 6-DoF IMU
(accel + gyro, with bias + noise), and "visual features" that are a fixed
random projection of true frame-to-frame motion plus clutter -- so a VIO
network *can* recover pose from them (learnable), while the problem keeps
KITTI's structure (translation + rotation regression per frame pair).

Targets are relative pose: translation (3,) in meters, rotation (3,) as
an axis-angle increment -- matching UL-VIO's t-RMSE / r-RMSE metrics.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["VIOStream", "vio_batch"]


def _traj(rng, steps: int):
    """Smooth random trajectory: returns per-step (dt_xyz, drot_axis_angle)."""
    acc = rng.standard_normal((steps, 3)) * 0.05
    vel = np.cumsum(acc, 0) * 0.1 + np.array([1.0, 0.0, 0.0]) * 0.3
    dpos = vel * 0.1
    dang = np.cumsum(rng.standard_normal((steps, 3)) * 0.01, 0) * 0.05
    return dpos.astype(np.float32), dang.astype(np.float32)


@dataclasses.dataclass
class VIOStream:
    batch: int = 16
    feat_dim: int = 256     # stub of the image-pair encoder output
    imu_rate: int = 10      # imu samples per frame interval
    seed: int = 0
    step: int = 0

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def next_batch(self) -> Dict[str, np.ndarray]:
        out = vio_batch(self.batch, self.feat_dim, self.imu_rate,
                        np.random.default_rng(
                            np.random.SeedSequence([self.seed, self.step])))
        self.step += 1
        return out


_PROJ = {}


def _proj(rng_seed: int, feat_dim: int) -> np.ndarray:
    key = (rng_seed, feat_dim)
    if key not in _PROJ:
        _PROJ[key] = np.random.default_rng(rng_seed).standard_normal(
            (6, feat_dim)).astype(np.float32)
    return _PROJ[key]


def vio_batch(batch: int, feat_dim: int, imu_rate: int, rng):
    dpos, dang = _traj(rng, batch)
    pose = np.concatenate([dpos, dang], -1)               # (B, 6)
    proj = _proj(1234, feat_dim)
    vis = pose @ proj + rng.standard_normal(
        (batch, feat_dim)).astype(np.float32) * 0.1       # visual features
    imu = np.repeat(pose[:, None, :], imu_rate, 1)
    imu = imu + rng.standard_normal(imu.shape).astype(np.float32) * 0.05
    imu[..., :3] += 0.02                                  # accel bias
    return {
        "visual": vis.astype(np.float32),                 # (B, F)
        "imu": imu.astype(np.float32),                    # (B, R, 6)
        "pose": pose.astype(np.float32),                  # (B, 6) target
    }
