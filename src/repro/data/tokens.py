"""Deterministic, shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard), so:
  * resume after preemption is exact (the iterator state is one integer,
    saved in the checkpoint manifest);
  * each host generates only its shard (no cross-host data motion);
  * straggler mitigation: a lagging host can *re-balance* -- the
    ``rebalance(num_shards)`` view re-partitions the same global stream
    without changing the data any step sees.

The stream is a Markov-ish mixture so models actually learn (loss drops):
token t+1 is a noisy affine function of token t within a banded vocab.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0
    shard: int = 0
    num_shards: int = 1
    frontend: str = "none"
    d_model: int = 0
    n_patches: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards

    def rebalance(self, num_shards: int, shard: int) -> "TokenStream":
        return dataclasses.replace(self, num_shards=num_shards, shard=shard)

    def state_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: Dict) -> None:
        self.seed, self.step = int(d["seed"]), int(d["step"])

    def _batch_np(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b, s, v = self.local_batch, self.seq_len, self.vocab
        band = max(v // 64, 2)
        x = np.empty((b, s + 1), np.int32)
        x[:, 0] = rng.integers(0, v, b)
        # per-sequence drift rate: the model learns p(next | cur) quickly
        rate = rng.integers(1, band, (b, 1))
        noise = rng.integers(0, 3, (b, s)) - 1
        for t in range(s):
            x[:, t + 1] = (x[:, t] + rate[:, 0] + noise[:, t]) % v
        out = {"tokens": x[:, :-1], "labels": x[:, 1:]}
        if self.frontend == "audio":
            emb = rng.standard_normal((b, s, self.d_model)).astype(np.float32)
            out = {"frame_embeds": emb * 0.02, "labels": out["labels"]}
        elif self.frontend == "vision":
            pe = rng.standard_normal(
                (b, self.n_patches, self.d_model)).astype(np.float32)
            out["patch_embeds"] = pe * 0.02
        return out

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        out = {k: jnp.asarray(v) for k, v in self._batch_np(self.step).items()}
        self.step += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()
