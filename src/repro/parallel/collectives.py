"""Distributed-optimization tricks: compressed gradients, overlap helpers.

Gradient compression uses the paper's own wire format: Posit(8,0) codes
with a per-tensor power-of-two scale and *error feedback* (the residual of
each step's quantization is added back before the next quantization), the
standard trick that keeps compressed-SGD convergence unbiased in practice.
On the wire this cuts DP all-reduce bytes 4x vs f32 (2x vs bf16) -- the
same bandwidth argument the paper makes for off-chip traffic, applied to
the inter-pod DCN hop.

The compressed all-reduce is expressed at the sharding level: gradients
are quantized *before* the psum that jit inserts for data-parallel
reduction, so the collective moves int8 payloads.  (In shard_map terms:
quantize -> psum -> dequantize; in pjit terms the pattern lowers to the
same.)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import codec as codec_mod
from ..core import formats as fmt

__all__ = ["compress_tree", "decompress_tree", "error_feedback_update",
           "psum_compressed"]


def _po2_scale(x: jax.Array) -> jax.Array:
    """RMS-centered po2 scale: posit8 precision is densest near +-1, so
    center the gradient distribution there (absmax-to-maxpos scaling
    parks most values in the coarse regime tail; see quant.format_scale).
    Posit8's 2^+-6 range absorbs the tail above RMS."""
    r = jnp.sqrt(jnp.mean(jnp.square(x))) + 1e-30
    return jnp.exp2(jnp.round(jnp.log2(r)))


def compress_tree(grads, residuals=None):
    """Quantize a gradient pytree to posit8 codes (+ scales), folding in
    error-feedback residuals.  Returns (codes_tree, scales_tree,
    new_residuals)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = (jax.tree.leaves(residuals) if residuals is not None
                  else [jnp.zeros_like(l) for l in leaves])
    codes, scales, new_res = [], [], []
    for g, r in zip(leaves, res_leaves):
        g_fb = g + r.astype(g.dtype)
        s = _po2_scale(g_fb)
        c = codec_mod.encode(fmt.POSIT8, (g_fb / s).astype(jnp.float32))
        deq = codec_mod.decode(fmt.POSIT8, c) * s
        codes.append(c.astype(jnp.int8))
        scales.append(s)
        new_res.append((g_fb.astype(jnp.float32) - deq).astype(g.dtype))
    return (jax.tree.unflatten(treedef, codes),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, new_res))


def decompress_tree(codes, scales):
    return jax.tree.map(
        lambda c, s: codec_mod.decode(fmt.POSIT8, c.astype(jnp.int32)) * s,
        codes, scales)


def error_feedback_update(grads, residuals):
    """One compress/decompress round-trip as used inside the train step
    (the psum itself is inserted by jit from the batch sharding)."""
    codes, scales, new_res = compress_tree(grads, residuals)
    return decompress_tree(codes, scales), new_res


def psum_compressed(grads, axis_name: str, residuals=None):
    """shard_map-space compressed all-reduce: posit8 on the wire.

    Note: summing decoded posit8 values is done in f32 (the quire
    analogue); each participant contributes one quantization error, which
    error feedback absorbs across steps."""
    codes, scales, new_res = compress_tree(grads, residuals)
    # max-scale alignment so codes are summable: rescale codes to the
    # global scale, then one psum in int32 (wire: 4B but 1B payload
    # entropy; TPU ICI all-reduces int8 natively -- documented proxy).
    def reduce_one(c, s):
        s_max = jax.lax.pmax(s, axis_name)
        v = codec_mod.decode(fmt.POSIT8, c.astype(jnp.int32)) * s
        v = jax.lax.psum(v, axis_name)
        return v, s_max
    flat_c, treedef = jax.tree.flatten(codes)
    flat_s = jax.tree.leaves(scales)
    out = [reduce_one(c, s)[0] for c, s in zip(flat_c, flat_s)]
    return jax.tree.unflatten(treedef, out), new_res
