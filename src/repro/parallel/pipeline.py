"""Optional pipeline parallelism: GPipe-style microbatch pipeline over a
mesh axis (normally 'pod') via shard_map + collective_permute.

At 1000+ node scale, DCN between pods favors pipeline transfers (one
boundary activation per microbatch) over FSDP all-gathers.  This module
gives the framework that option: layers are split into S contiguous
stages; each stage lives on one slice of the ``stage`` axis; microbatches
flow through with the classic GPipe schedule (S + M - 1 ticks).

Semantics are validated against the unpipelined model in
tests/test_pipeline.py on 8 fake devices.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(mesh: Mesh, axis: str, stage_fn: Callable,
                   stage_params, x: jax.Array, n_microbatches: int):
    """Run ``stage_fn(params_s, x) -> x`` as an ``axis``-way pipeline.

    stage_params: pytree whose leaves have leading dim = n_stages
                  (stage s's slice lives on stage s's devices).
    x:            (batch, ...) global input; batch must divide
                  n_microbatches.
    Returns the final-stage output, gathered to all stages (replicated),
    matching the semantics of sequentially applying all stages.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert x.shape[0] % n_microbatches == 0
    mb = x.shape[0] // n_microbatches

    def per_stage(params_s, x_all):
        # params_s: this stage's params (leading stage dim of size 1)
        params_s = jax.tree.map(lambda t: t[0], params_s)
        stage_id = jax.lax.axis_index(axis)
        ticks = n_stages + n_microbatches - 1
        buf = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        outs = jnp.zeros((n_microbatches, mb) + x_all.shape[1:],
                         x_all.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jax.lax.dynamic_slice_in_dim(
                x_all, (jnp.clip(t, 0, n_microbatches - 1)) * mb, mb, 0)
            live_in = jnp.where((stage_id == 0) & (t < n_microbatches),
                                inject, buf)
            y = stage_fn(params_s, live_in)
            # last stage records microbatch (t - (S-1)) when valid
            out_idx = t - (n_stages - 1)
            outs = jax.lax.cond(
                (stage_id == n_stages - 1) & (out_idx >= 0),
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # shift boundary activations stage s -> s+1 (ring; the wrap
            # value into stage 0 is ignored -- it injects fresh data)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        out = outs.reshape((n_microbatches * mb,) + x_all.shape[1:])
        # replicate final-stage result to every stage (psum of one-hot)
        mask = (stage_id == n_stages - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    other_axes = [a for a in mesh.axis_names if a != axis]
    pspec_params = P(axis)
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(pspec_params, P()),     # params stage-sharded, x replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
