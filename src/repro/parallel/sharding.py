"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Models annotate activations with *logical* axis names via ``shard(x, ...)``
and parameters get PartitionSpecs from path-based rules.  The mapping
logical->mesh is held in a context; outside a mesh context every
annotation is a no-op, so the same model code runs single-device (smoke
tests) and on the production mesh (dry-run) unchanged.

Axis conventions (single pod mesh ('data','model'), multi-pod
('pod','data','model')):

  batch   -> ('pod','data')   data parallel across pods + within pod
  seq     -> None normally; ('pod','data') for SP long-context decode
  heads/ff/vocab/experts -> 'model'   tensor/expert parallel
  params: in-dim 'data' (FSDP, within-pod only: DCN-friendly), out-dim
  'model'; Megatron pairing exceptions shard the *contraction* dim of the
  second matmul by 'model'.

Any rule whose axis does not evenly divide the tensor dim is dropped for
that tensor (e.g. kv_heads=8 on a 16-way 'model' axis replicates instead
of erroring) -- production meshes must never hard-fail on a model shape.
"""

from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "activation_rules", "use_mesh", "current_mesh", "shard", "param_pspec",
    "param_sharding_tree", "logical_pspec", "batch_pspec", "DATA_AXES",
    "cache_pspec", "paged_cache_pspec", "cache_sharding_tree",
    "split_devices",
]

_ctx = threading.local()

# logical activation axis -> mesh axes (tried in order, dropped if indivisible)
ACT_RULES = {
    "batch": ("pod", "data"),
    "batch_nopod": ("data",),
    "seq_sp": ("pod", "data"),     # sequence parallelism for long context
    "seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "capacity": (),
    "state": (),
    None: (),
}

DATA_AXES = ("pod", "data")


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make ``mesh`` the ambient mesh for ``shard`` annotations.

    We deliberately do NOT enter jax.sharding.use_mesh (sharding-in-types
    mode): all jit entry points pass explicit NamedShardings, and
    ``with_sharding_constraint`` accepts them without an ambient mesh.
    """
    prev = getattr(_ctx, "mesh", None)
    _ctx.mesh = mesh
    try:
        yield mesh
    finally:
        _ctx.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def _mesh_axes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve(mesh: Mesh, dim: int, logical: Optional[str], used: set):
    """Logical name -> tuple of mesh axes that evenly divide ``dim``.
    Axes already claimed by another dim of the same tensor are skipped
    (a mesh axis may shard at most one dim)."""
    axes = _mesh_axes(mesh)
    want = ACT_RULES.get(logical, ())
    out = []
    prod = 1
    for a in want:
        if a in axes and a not in used and dim % (prod * axes[a]) == 0:
            out.append(a)
            prod *= axes[a]
    used.update(out)
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def logical_pspec(mesh: Mesh, shape: Sequence[int],
                  logical: Sequence[Optional[str]]) -> P:
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    return P(*[_resolve(mesh, d, l, used) for d, l in zip(shape, logical)])


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical names (no-op without mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_pspec(mesh, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def split_devices(devices=None, prefill_frac: float = 0.5):
    """Split a device list into (prefill, decode) slices for
    disaggregated serving (``serve/disagg.py``).

    Prefill is compute-bound and decode memory-bound, so the split is a
    roofline knob: ``prefill_frac`` of the devices go to the prefill
    worker (at least one each side).  With a SINGLE device both workers
    share it -- the two jitted programs still overlap through async
    dispatch, which is the in-process default the tests run."""
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    assert devices, "no devices"
    if len(devices) == 1:
        return devices, devices
    cut = min(max(int(len(devices) * prefill_frac), 1), len(devices) - 1)
    return devices[:cut], devices[cut:]


def batch_pspec(mesh: Mesh) -> P:
    axes = [a for a in DATA_AXES if a in mesh.axis_names]
    return P(tuple(axes) if len(axes) > 1 else axes[0])


# ---------------------------------------------------------------------------
# Parameter sharding rules (path + shape based)
# ---------------------------------------------------------------------------

# paths whose *contraction* dim is model-sharded (Megatron row-parallel:
# the second matmul of each pair)
_ROW_PARALLEL = ("*wo*", "*down*", "*out_proj*", "*o_proj*", "*w2*")
# paths that are expert-stacked: leading (post-layer-stack) dim is experts
_EXPERT = ("*experts*",)
# paths stacked over layers by scan (leading dim = n_layers)
_LAYER_STACKED = ("layers/*", "*/layers/*", "groups/*", "*/groups/*")
# embedding tables: (vocab, embed).  lm_head is (embed, vocab) -- the
# DEFAULT column-parallel rule (in->data, out->model) is the correct one
# (listing it here sharded d_model as if it were vocab and forced a
# data->model reshard of the logits; §Perf it2).
_EMBED = ("*embedding*", "*embed/table*")
# 1-D / small params: replicate.  PackedTensor v2 sub-leaves land here:
# '*scales*' matches the (G, N) group-scale plane and '*mask*' the gating
# map -- both are tiny next to 'words' and every shard's kernel needs the
# full N stripe of scales, so replication is the correct layout; 'words'
# (the packed codes) follow the normal matrix rules via the default path.
_REPLICATED_SUFFIX = ("*norm*", "*bias*", "*alpha*", "*scale*", "*dt*",
                      "*decay*", "*a_log*", "*conv*", "*mask*", "*mix_*",
                      "*bonus*", "*count*")


def _match(path: str, pats) -> bool:
    return any(fnmatch.fnmatch(path, p) for p in pats)


def param_pspec(mesh: Mesh, path: str, shape: Sequence[int]) -> P:
    """PartitionSpec for one parameter from its path + shape."""
    nd = len(shape)
    if nd == 0:
        return P()
    specs: list = [None] * nd
    dims = list(range(nd))
    if _match(path, _LAYER_STACKED) and nd >= 2:
        dims = dims[1:]  # leading layer-stack dim: never sharded
    if _match(path, _REPLICATED_SUFFIX) or len(dims) <= 1:
        return P(*specs)
    axes = _mesh_axes(mesh)

    def fit(dim_idx: int, axis: str) -> bool:
        return axis in axes and shape[dim_idx] % axes[axis] == 0 and \
            specs[dim_idx] is None and axis not in specs

    if _match(path, _EXPERT):
        # (E, in, out): EP on experts, FSDP on in-dim
        if fit(dims[0], "model"):
            specs[dims[0]] = "model"
        if len(dims) >= 2 and fit(dims[1], "data"):
            specs[dims[1]] = "data"
        return P(*specs)
    if _match(path, _EMBED):
        # (vocab, embed): TP on vocab, FSDP on embed
        if fit(dims[0], "model"):
            specs[dims[0]] = "model"
        if len(dims) >= 2 and fit(dims[-1], "data"):
            specs[dims[-1]] = "data"
        return P(*specs)
    if _match(path, _ROW_PARALLEL):
        # (in, out): contraction dim on 'model', out on 'data'
        if fit(dims[0], "model"):
            specs[dims[0]] = "model"
        if fit(dims[-1], "data"):
            specs[dims[-1]] = "data"
        return P(*specs)
    # default column-parallel: in-dim FSDP('data'), out-dim TP('model')
    if fit(dims[-1], "model"):
        specs[dims[-1]] = "model"
    if fit(dims[0], "data"):
        specs[dims[0]] = "data"
    return P(*specs)


def param_sharding_tree(mesh: Mesh, params):
    """Pytree of NamedShardings matching ``params`` (works on
    ShapeDtypeStructs too, for .lower()).  PackedTensor nodes become
    PackedTensors holding shardings (same pytree structure)."""
    from ..core.policy import flatten_with_paths

    flat = flatten_with_paths(params)
    specs = {p: NamedSharding(mesh, param_pspec(mesh, p, v.shape))
             for p, v in flat}

    def rebuild(node, path=""):
        if isinstance(node, dict):
            return {k: rebuild(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(v, f"{path}/{i}" if path else str(i))
                 for i, v in enumerate(node)]
            return type(node)(t)
        if node is None:
            return None
        if hasattr(node, "words") and hasattr(node, "scales"):
            # keep ALL aux (shape/spec/group/version): the sharding tree
            # must stay pytree-compatible with the parameter tree
            return dataclasses.replace(
                node,
                words=specs[f"{path}/words"],
                scales=specs[f"{path}/scales"],
                mask=specs[f"{path}/mask"])
        return specs[path]

    return rebuild(params)


# ---------------------------------------------------------------------------
# Decode-cache sharding rules
# ---------------------------------------------------------------------------

def _fit_axes(shape: Sequence[int], axes: dict, dim_idx: int, names) -> list:
    """Greedily stack mesh axes onto ``shape[dim_idx]`` while the dim
    stays divisible -- THE divisibility rule of the cache planes
    (contiguous and paged); change it here only."""
    got = []
    prod = 1
    for a in names:
        if a in axes and shape[dim_idx] % (prod * axes[a]) == 0:
            got.append(a)
            prod *= axes[a]
    return got


def cache_pspec(mesh: Mesh, path: str, shape: Sequence[int],
                batch: int) -> P:
    """Sharding for KV-cache / SSM-state leaves (stacked over layers on
    dim 0).  Batch dim shards on ('pod','data') when divisible; for
    global_batch too small (long_500k: B=1) the *sequence* dim takes the
    data axes instead -- sequence parallelism for long-context decode."""
    nd = len(shape)
    specs: list = [None] * nd
    axes = _mesh_axes(mesh)

    def fit_axes(dim_idx, names):
        return _fit_axes(shape, axes, dim_idx, names)

    # find batch dim: first dim equal to batch (after the layer-stack dim)
    bdim = None
    for i in range(1, nd):
        if shape[i] == batch:
            bdim = i
            break
    data_axes = [a for a in DATA_AXES if a in axes]
    placed_data = False
    if bdim is not None:
        got = fit_axes(bdim, data_axes)
        if got:
            specs[bdim] = tuple(got) if len(got) > 1 else got[0]
            placed_data = True
    if not placed_data and nd >= 3:
        # SP fallback: shard the longest remaining dim (the seq axis)
        cand = max(range(1, nd), key=lambda i: shape[i])
        got = fit_axes(cand, data_axes)
        if got and specs[cand] is None:
            specs[cand] = tuple(got) if len(got) > 1 else got[0]
    # model axis on the innermost (head/feature) dim that divides --
    # iterate from the last dim so seq dims are the last resort
    if "model" in axes:
        for i in reversed(range(1, nd)):
            if specs[i] is None and shape[i] % axes["model"] == 0:
                specs[i] = "model"
                break
    return P(*specs)


def paged_cache_pspec(mesh: Mesh, path: str, shape: Sequence[int]) -> P:
    """Sharding for PAGED decode-cache leaves.

    Pool pages REPLICATE across the data axes: any request's page-table
    gather may touch any physical page, so splitting the page dim turns
    every block read into an all-gather (XLA's 'involuntary full
    rematerialization').  'model' rides the innermost head/feature dim
    that divides, like the contiguous cache.  State-slab leaves follow
    the same rule: the slab dim (a page dim in all but name -- any
    request's slab gather may touch any slab) replicates.
    ``page_table``/``slab_table``/``positions`` shard their request
    (batch) dim on the data axes -- requests, not pages, are the
    data-parallel unit of continuous batching."""
    key = path.rsplit("/", 1)[-1]
    axes = _mesh_axes(mesh)
    nd = len(shape)
    specs: list = [None] * nd
    if key in ("page_table", "slab_table", "positions"):
        # (B, NP) / (B,): one top-level copy, batch leads (the layer
        # scan broadcasts it; there is no layer axis to skip anymore)
        got = _fit_axes(shape, axes, 0,
                        [x for x in DATA_AXES if x in axes])
        if got:
            specs[0] = tuple(got) if len(got) > 1 else got[0]
        return P(*specs)
    if "model" in axes:
        for i in reversed(range(min(3, nd - 1), nd)):
            if shape[i] % axes["model"] == 0:
                specs[i] = "model"
                break
    return P(*specs)


def cache_sharding_tree(mesh: Mesh, cache, batch: int):
    from ..core.policy import flatten_with_paths

    flat = flatten_with_paths(cache)
    paged = any(p.rsplit("/", 1)[-1] in ("page_table", "slab_table")
                for p, _ in flat)
    if paged:
        specs = {p: NamedSharding(mesh, paged_cache_pspec(mesh, p, v.shape))
                 for p, v in flat}
    else:
        specs = {p: NamedSharding(mesh, cache_pspec(mesh, p, v.shape, batch))
                 for p, v in flat}

    def rebuild(node, path=""):
        if isinstance(node, dict):
            return {k: rebuild(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rebuild(v, f"{path}/{i}" if path else str(i))
                              for i, v in enumerate(node))
        if node is None:
            return None
        return specs[path]

    return rebuild(cache)
