from . import sharding, collectives, pipeline  # noqa: F401
