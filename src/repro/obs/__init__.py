"""Serving-plane observability: metrics registry, tracing, stats.

See docs/observability.md for the event taxonomy, span hierarchy and
exporter formats.
"""

from .metrics import Counter, Gauge, Histogram, MetricRegistry, bind_counters
from .stats import pctl_ms, percentiles, summarize, time_call
from .trace import (
    LIFECYCLE_EVENTS,
    NULL_RECORDER,
    SPAN_KINDS,
    TraceRecorder,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "bind_counters",
    "pctl_ms",
    "percentiles",
    "summarize",
    "time_call",
    "LIFECYCLE_EVENTS",
    "NULL_RECORDER",
    "SPAN_KINDS",
    "TraceRecorder",
    "validate_chrome_trace",
]
