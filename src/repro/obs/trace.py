"""Request-lifecycle tracing and step-span timelines.

``TraceRecorder`` captures two things into one bounded ring buffer:

- **lifecycle events** — instant markers for a request's progress
  through the serving plane::

      SUBMIT -> ADMIT -> PREFILL_CHUNK... -> PREFILL_COMPLETE
             -> HANDOFF -> DECODE_DISPATCH / DECODE_SYNC
             -> RETIRE | PREEMPT | BOUNCE

- **spans** — durations of engine step phases (``capacity`` / ``admit``
  / ``prefill`` / ``decode_dispatch`` / ``decode_sync``) and channel
  push/pull, recorded via the ``span()`` context manager.

The ring is bounded (``capacity`` entries, default 64Ki); the oldest
entries are evicted under pressure.  Per-kind event **counts** and the
sums of numeric event args are kept in separate monotonic accumulators
that never evict, so closed-form tie-outs (decode dispatches
``(gen-1)/K``, handoff bytes ``pages * page_handoff_bytes``) hold
regardless of ring capacity.

A disabled recorder (``NULL_RECORDER``) costs one predicted branch per
telemetry call; it records nothing and its ``span()`` returns a shared
no-op context manager.  Telemetry never touches device math — all
recording is host-side bookkeeping after values already exist.

Exports: Chrome trace-event JSON (open in Perfetto / chrome://tracing),
a JSONL event stream, and SLO metrics (TTFT, TPOT, queue wait, prefill
stall, end-to-end) derived from lifecycle timestamps.
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional

from .stats import summarize

__all__ = [
    "LIFECYCLE_EVENTS",
    "SPAN_KINDS",
    "TraceRecorder",
    "NULL_RECORDER",
    "validate_chrome_trace",
]

LIFECYCLE_EVENTS = (
    "SUBMIT",
    "ADMIT",
    "PREFILL_CHUNK",
    "PREFILL_COMPLETE",
    "HANDOFF",
    "DECODE_DISPATCH",
    "DECODE_SYNC",
    "RETIRE",
    "PREEMPT",
    "BOUNCE",
)

SPAN_KINDS = (
    "step",
    "capacity",
    "admit",
    "prefill",
    "decode_dispatch",
    "decode_sync",
    "channel_push",
    "channel_pull",
)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("rec", "kind", "rid", "args", "t0")

    def __init__(self, rec: "TraceRecorder", kind: str, rid, args):
        self.rec = rec
        self.kind = kind
        self.rid = rid
        self.args = args

    def __enter__(self):
        self.t0 = self.rec._now()
        return self

    def __exit__(self, *exc):
        self.rec._end_span(self)
        return False


class TraceRecorder:
    """Bounded-ring recorder for lifecycle events and phase spans."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._counts: Dict[str, int] = {}
        self._sums: Dict[str, float] = {}
        self.dropped = 0
        # Optional MetricRegistry: span durations are also observed into
        # "span/<kind>" histograms there, so the Prometheus snapshot
        # carries phase-latency percentiles.
        self.hist_registry = None
        self._t0 = time.perf_counter()

    # -- recording ---------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def event(self, kind: str, rid: Optional[int] = None, **args) -> None:
        """Record an instant lifecycle event. No-op when disabled."""
        if not self.enabled:
            return
        self._counts[kind] = self._counts.get(kind, 0) + 1
        for k, v in args.items():
            if isinstance(v, (int, float)):
                key = f"{kind}.{k}"
                self._sums[key] = self._sums.get(key, 0) + v
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append({"ph": "i", "ts": self._now(), "kind": kind,
                           "rid": rid, "args": args})

    def span(self, kind: str, rid: Optional[int] = None, **args):
        """Context manager timing a phase. No-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, kind, rid, args)

    def _end_span(self, s: _Span) -> None:
        t1 = self._now()
        self._counts[s.kind] = self._counts.get(s.kind, 0) + 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append({"ph": "X", "ts": s.t0, "dur": t1 - s.t0,
                           "kind": s.kind, "rid": s.rid, "args": s.args})
        if self.hist_registry is not None:
            self.hist_registry.histogram(f"span/{s.kind}").observe(t1 - s.t0)

    def clear(self) -> None:
        self._ring.clear()
        self._counts.clear()
        self._sums.clear()
        self.dropped = 0
        self._t0 = time.perf_counter()

    # -- queries -----------------------------------------------------

    def __len__(self) -> int:
        return len(self._ring)

    def count(self, kind: str) -> int:
        """Exact number of events/spans of ``kind`` (eviction-proof)."""
        return self._counts.get(kind, 0)

    def arg_sum(self, kind: str, key: str) -> float:
        """Exact sum of a numeric event arg (eviction-proof)."""
        return self._sums.get(f"{kind}.{key}", 0)

    def events(self, kind: Optional[str] = None,
               rid: Optional[int] = None) -> List[dict]:
        out = []
        for e in self._ring:
            if kind is not None and e["kind"] != kind:
                continue
            if rid is not None and e["rid"] != rid:
                continue
            out.append(e)
        return out

    # -- SLO derivation ----------------------------------------------

    def request_slo(self) -> Dict[int, Dict[str, float]]:
        """Per-request latency metrics (ms) from lifecycle timestamps.

        - ``queue_wait_ms``    = ADMIT - SUBMIT
        - ``ttft_ms``          = PREFILL_COMPLETE - SUBMIT (the first
          token is sampled from the prefill logits)
        - ``prefill_stall_ms`` = PREFILL_COMPLETE - ADMIT
        - ``e2e_ms``           = RETIRE - SUBMIT
        - ``tpot_ms``          = (RETIRE - PREFILL_COMPLETE) / (gen - 1)

        Derived from ring contents; requests whose SUBMIT was evicted
        are skipped.
        """
        first: Dict[int, Dict[str, float]] = {}
        last_retire: Dict[int, dict] = {}
        for e in self._ring:
            rid = e["rid"]
            if rid is None or e["ph"] != "i":
                continue
            kinds = first.setdefault(rid, {})
            if e["kind"] not in kinds:
                kinds[e["kind"]] = e["ts"]
            if e["kind"] == "RETIRE":
                last_retire[rid] = e
        out: Dict[int, Dict[str, float]] = {}
        for rid, kinds in first.items():
            if "SUBMIT" not in kinds:
                continue
            sub = kinds["SUBMIT"]
            rec: Dict[str, float] = {}
            if "ADMIT" in kinds:
                rec["queue_wait_ms"] = (kinds["ADMIT"] - sub) * 1e3
            if "PREFILL_COMPLETE" in kinds:
                pc = kinds["PREFILL_COMPLETE"]
                rec["ttft_ms"] = (pc - sub) * 1e3
                if "ADMIT" in kinds:
                    rec["prefill_stall_ms"] = (pc - kinds["ADMIT"]) * 1e3
            if rid in last_retire:
                ret = last_retire[rid]
                rec["e2e_ms"] = (ret["ts"] - sub) * 1e3
                gen = ret["args"].get("generated", 0)
                if gen > 1 and "PREFILL_COMPLETE" in kinds:
                    rec["tpot_ms"] = (ret["ts"] - kinds["PREFILL_COMPLETE"]) * 1e3 / (gen - 1)
            if rec:
                out[rid] = rec
        return out

    def slo_summary(self) -> Dict[str, Dict[str, float]]:
        """Aggregate p50/p95/p99 over every per-request SLO metric."""
        cols: Dict[str, List[float]] = {}
        for rec in self.request_slo().values():
            for k, v in rec.items():
                cols.setdefault(k, []).append(v)
        return {k: summarize(v) for k, v in sorted(cols.items())}

    # -- exporters ---------------------------------------------------

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        One process; tid 0 is the engine step lane, tid ``rid + 1`` is
        the per-request lane.  Spans are ``ph="X"`` complete events,
        lifecycle events are ``ph="i"`` instants; timestamps in µs.
        """
        evs: List[dict] = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "repro-serve"}},
            {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
             "args": {"name": "engine"}},
        ]
        rids = sorted({e["rid"] for e in self._ring if e["rid"] is not None})
        for rid in rids:
            evs.append({"ph": "M", "pid": 0, "tid": int(rid) + 1,
                        "name": "thread_name",
                        "args": {"name": f"req {rid}"}})
        for e in self._ring:
            rid = e["rid"]
            tid = 0 if rid is None else int(rid) + 1
            args = dict(e["args"])
            if rid is not None:
                args["rid"] = int(rid)
            out = {"name": e["kind"], "pid": 0, "tid": tid,
                   "ts": e["ts"] * 1e6, "args": args}
            if e["ph"] == "X":
                out["ph"] = "X"
                out["cat"] = "span"
                out["dur"] = e["dur"] * 1e6
            else:
                out["ph"] = "i"
                out["cat"] = "lifecycle"
                out["s"] = "t"
            evs.append(out)
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def write_jsonl(self, path: str) -> None:
        """One JSON object per ring entry, in recording order."""
        with open(path, "w") as f:
            for e in self._ring:
                f.write(json.dumps(e) + "\n")


NULL_RECORDER = TraceRecorder(capacity=0, enabled=False)


def validate_chrome_trace(obj: dict) -> Dict[str, int]:
    """Schema-check a Chrome trace-event JSON object.

    Raises ``ValueError`` on the first violation; returns counts of
    spans / instants / metadata events when valid.  This is what
    ``bench_serve.py --smoke`` and the CI trace step run against
    emitted artifacts, so a malformed export fails loudly rather than
    silently rendering empty in Perfetto.
    """
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        raise ValueError("top level must be an object with a traceEvents list")
    n = {"X": 0, "i": 0, "M": 0}
    for idx, e in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{idx}]"
        if not isinstance(e, dict):
            raise ValueError(f"{where}: not an object")
        ph = e.get("ph")
        if ph not in ("X", "i", "M", "B", "E", "C"):
            raise ValueError(f"{where}: bad ph {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"{where}: missing name")
        for k in ("pid", "tid"):
            if not isinstance(e.get(k), int):
                raise ValueError(f"{where}: missing int {k}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: bad dur {dur!r}")
        n[ph] = n.get(ph, 0) + 1
    return {"spans": n["X"], "instants": n["i"], "metadata": n["M"],
            "total": len(obj["traceEvents"])}
