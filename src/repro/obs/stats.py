"""Shared timing/percentile helpers for benches and telemetry.

This is the single home for the small statistics helpers that used to
be copy-pasted across ``benchmarks/common.py``, ``bench_serve.py`` and
``bench_decode.py``.  The bench modules now import from here (directly
or via the ``benchmarks.common`` re-export), so median/percentile
semantics cannot drift between benches.
"""

from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

__all__ = ["time_call", "pctl_ms", "percentiles", "summarize"]


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of ``fn(*args)`` in microseconds.

    Blocks on the result via ``block_until_ready`` when available, so
    dispatched device work is included in the measurement.
    """
    for _ in range(warmup):
        r = fn(*args)
        _block(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(r) -> None:
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    elif isinstance(r, (tuple, list)):
        for x in r:
            if hasattr(x, "block_until_ready"):
                x.block_until_ready()


def pctl_ms(seconds: Sequence[float], q: float) -> float:
    """``q``-th percentile of a list of second-valued samples, in ms.

    Matches the historical bench expression
    ``float(np.percentile(xs, q) * 1e3)`` exactly (percentile first,
    then unit conversion).
    """
    return float(np.percentile(np.asarray(seconds, dtype=np.float64), q) * 1e3)


def percentiles(values: Sequence[float], qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p95": ...}`` over raw samples (no unit change)."""
    arr = np.asarray(values, dtype=np.float64)
    return {f"p{g:g}": float(np.percentile(arr, g)) for g in qs}


def summarize(values: Sequence[float]) -> Dict[str, float]:
    """Count/mean/min/max plus p50/p95/p99 of raw samples."""
    arr = np.asarray(values, dtype=np.float64)
    if arr.size == 0:
        return {"n": 0}
    out = {
        "n": int(arr.size),
        "mean": float(arr.mean()),
        "min": float(arr.min()),
        "max": float(arr.max()),
    }
    out.update(percentiles(arr))
    return out
