"""Typed metric registry for the serving plane.

Three metric kinds:

- ``Counter``  — monotonically incremented int/float, resettable.
- ``Gauge``    — point-in-time value; either set explicitly or backed
  by a zero-arg callable (used for pool utilization, prefix hit rate
  and the closed-form byte/dispatch models, so the owning object's hot
  path is never touched).
- ``Histogram`` — fixed log-spaced buckets with p50/p95/p99 snapshots.
  Observations clamp into under/overflow buckets; percentile queries
  return the geometric midpoint of the covering bucket, clamped to the
  observed min/max.

``bind_counters`` migrates the legacy class-level ``_COUNTERS`` tuple
pattern onto the registry: it installs data descriptors on the class so
pre-existing call sites (``self.steps_run += 1``, ``setattr(self, c, 0)``
in ``reset_counters``, and plain attribute reads) keep working verbatim
while the values live in registry ``Counter`` objects.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Iterable, List, Optional, Union

Number = Union[int, float]

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "bind_counters",
]


class Counter:
    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Counter({self.name}={self.value})"


class Gauge:
    kind = "gauge"
    __slots__ = ("name", "fn", "_value")

    def __init__(self, name: str, fn: Optional[Callable[[], Number]] = None):
        self.name = name
        self.fn = fn
        self._value: Number = 0

    @property
    def value(self) -> Number:
        if self.fn is not None:
            return self.fn()
        return self._value

    def set(self, v: Number) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is callback-backed")
        self._value = v

    def reset(self) -> None:
        if self.fn is None:
            self._value = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Fixed log-bucket histogram over (lo, hi) with N buckets/decade."""

    kind = "histogram"

    def __init__(self, name: str, lo: float = 1e-7, hi: float = 1e4, per_decade: int = 8):
        if lo <= 0 or hi <= lo:
            raise ValueError("need 0 < lo < hi")
        self.name = name
        self.lo = lo
        self.per_decade = per_decade
        self.n_buckets = int(math.ceil(math.log10(hi / lo) * per_decade)) + 2
        self.counts: List[int] = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        i = int(math.floor(math.log10(v / self.lo) * self.per_decade)) + 1
        return min(i, self.n_buckets - 1)

    def _edge(self, i: int) -> float:
        # Lower edge of bucket i (i >= 1); bucket 0 is underflow.
        return self.lo * 10.0 ** ((i - 1) / self.per_decade)

    def observe(self, v: Number) -> None:
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                if i == 0:
                    return self.vmin
                mid = math.sqrt(self._edge(i) * self._edge(i + 1))
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax  # pragma: no cover - acc always reaches count

    @property
    def value(self) -> int:
        return self.count

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }

    def reset(self) -> None:
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Histogram({self.name}, n={self.count})"


class MetricRegistry:
    """Name -> metric map with get-or-create accessors.

    Metric names are slash-namespaced (``"engine/steps_run"``,
    ``"channel/handoff_bytes"``); one registry spans all layers of an
    engine so benches and exporters read from a single place.
    """

    def __init__(self):
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get_or_create(self, name: str, kind, factory):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, kind):
                raise TypeError(f"metric {name} is {m.kind}, wanted {kind.__name__.lower()}")
            return m
        m = factory()
        self._metrics[name] = m
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str, fn: Optional[Callable[[], Number]] = None) -> Gauge:
        g = self._get_or_create(name, Gauge, lambda: Gauge(name, fn))
        if fn is not None and g.fn is None:
            g.fn = fn
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get_or_create(name, Histogram, lambda: Histogram(name, **kw))

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def value(self, name: str) -> Number:
        return self._metrics[name].value

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        """Zero counters/histograms and set-gauges; fn-gauges are live."""
        for m in self._metrics.values():
            m.reset()

    def prometheus_text(self, prefix: str = "repro") -> str:
        """Prometheus text-exposition snapshot of every metric."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _sanitize(f"{prefix}_{name}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {pname} summary")
                for q in (0.5, 0.95, 0.99):
                    lines.append(f'{pname}{{quantile="{q}"}} {_fmt(m.percentile(q * 100))}')
                lines.append(f"{pname}_sum {_fmt(m.total)}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def _fmt(v: Number) -> str:
    if isinstance(v, float) and not v.is_integer():
        return repr(v)
    return str(int(v))


class _CounterAttr:
    """Data descriptor routing a legacy counter attribute to the registry.

    Installed on the owning class by ``bind_counters``; takes priority
    over the instance ``__dict__`` so ``self.x += 1`` and
    ``setattr(self, x, 0)`` write through to the bound ``Counter``.
    """

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return obj._obs_counters[self.name].value

    def __set__(self, obj, value) -> None:
        obj._obs_counters[self.name].set(value)


def bind_counters(obj, registry: MetricRegistry, namespace: str,
                  names: Optional[Iterable[str]] = None) -> None:
    """Bind ``obj``'s legacy ``_COUNTERS`` attributes onto ``registry``.

    Each name becomes a ``Counter`` called ``"<namespace>/<name>"``,
    initialised to zero.  Descriptor installation on the class is
    idempotent; the per-instance binding lives in ``obj._obs_counters``.
    """
    cls = type(obj)
    names = tuple(names if names is not None else getattr(cls, "_COUNTERS", ()))
    for n in names:
        if not isinstance(getattr(cls, n, None), _CounterAttr):
            setattr(cls, n, _CounterAttr(n))
    bound = {}
    for n in names:
        c = registry.counter(f"{namespace}/{n}")
        c.reset()
        bound[n] = c
    obj._obs_counters = bound
